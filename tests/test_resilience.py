"""Fault-aware resilience layer: hardware-fault scenario expansion
(`repro.ft.hw_faults`), per-problem infeasibility in the batched solver
(`batch_schedule_hetero(strict=False)` + the 4-D scenario axis),
`hetero.resilience_codesign`'s (nominal, worst-case) front, and the DSE
service's `fault_event` re-schedule path.

The CI chaos job replays the service tests over a fixed seed matrix via
``REPRO_CHAOS_SEEDS`` (comma-separated; default "0,1,2")."""

import os

import numpy as np
import pytest

from repro.core import energymodel, hetero, partition, topology
from repro.core.accelerator import ConfigGrid
from repro.ft import hw_faults
from repro.ft.faults import FaultPlan, inject_chunk_faults
from repro.serving.dse_service import DSEService

# Guarded per-test (not module-level importorskip) so the deterministic
# tests below always run.
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    _HAS_HYPOTHESIS = False

    def _skip_property(f):
        return pytest.mark.skip(
            reason="property test needs hypothesis "
            "(pip install -r requirements-dev.txt)")(f)


SEEDS = tuple(int(s) for s in
              os.environ.get("REPRO_CHAOS_SEEDS", "0,1,2").split(","))
NETS = ("AlexNet", "MobileNet")


@pytest.fixture(scope="module")
def networks():
    return {n: topology.get_network(n) for n in NETS}


@pytest.fixture(scope="module")
def grid():
    return ConfigGrid.product(arrays=((16, 16), (32, 32), (64, 64)),
                              gb_psum_kb=(13, 54, 216),
                              gb_ifmap_kb=(27, 108))


class FakeClock:
    """Deterministic service time: advances only when told to."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# hw_faults: scenario declaration and expansion
# ---------------------------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError):
        hw_faults.CoreFailure(0, n=0)
    with pytest.raises(ValueError):
        hw_faults.DegradedArray(0)                 # disables nothing
    with pytest.raises(ValueError):
        hw_faults.DegradedArray(0, rows_lost=-1, cols_lost=2)
    # valid forms construct fine
    hw_faults.CoreFailure(1, n=2)
    hw_faults.DegradedArray(0, rows_lost=1)
    hw_faults.DegradedArray(0, cols_lost=3)


def test_apply_counts_clamps_and_range_checks():
    sc = hw_faults.FaultScenario(
        "s", (hw_faults.CoreFailure(0, n=5), hw_faults.CoreFailure(1)))
    out = hw_faults.apply_counts([2, 3], sc)
    assert out.tolist() == [0, 2]                  # clamped at 0
    bad = hw_faults.FaultScenario("b", (hw_faults.CoreFailure(7),))
    with pytest.raises(ValueError, match="out of range"):
        hw_faults.apply_counts([2, 3], bad)


def test_degrade_rows_clamps_and_preserves_other_columns(grid):
    deg = hw_faults.degrade_rows(grid, 10_000, 3)
    assert (deg.fields["rows"] == 1.0).all()       # clamped at 1
    np.testing.assert_array_equal(
        deg.fields["cols"], np.maximum(grid.fields["cols"] - 3, 1.0))
    for k, v in grid.fields.items():
        if k not in ("rows", "cols"):
            np.testing.assert_array_equal(deg.fields[k], v)


def test_scenario_key_is_hashable_identity():
    a = hw_faults.FaultScenario("a", (hw_faults.CoreFailure(0),))
    b = hw_faults.FaultScenario("b", (hw_faults.CoreFailure(0),))
    c = hw_faults.FaultScenario("c", (hw_faults.CoreFailure(1),))
    assert a.key() == b.key()                      # name-independent
    assert a.key() != c.key()
    assert len({a.key(), b.key(), c.key()}) == 2


def test_expand_scenarios_union_grid_and_dedup(grid):
    ct, cc = [0, 5], [2, 1]
    scens = [
        hw_faults.FaultScenario("loss0", (hw_faults.CoreFailure(0),)),
        hw_faults.FaultScenario(
            "deg1", (hw_faults.DegradedArray(1, rows_lost=2),)),
        hw_faults.FaultScenario(          # same degradation → same row
            "deg1b", (hw_faults.DegradedArray(1, rows_lost=2),)),
    ]
    b = hw_faults.expand_scenarios(grid, ct, cc, scens)
    assert b.names == ("nominal", "loss0", "deg1", "deg1b")
    assert b.nominal_first and b.n_scenarios == 4 and b.n_types == 2
    assert b.grid.n == 3                  # 2 nominal rows + ONE degraded
    np.testing.assert_array_equal(b.type_rows[0], [0, 1])
    np.testing.assert_array_equal(b.type_rows[1], [0, 1])
    np.testing.assert_array_equal(b.type_rows[2], [0, 2])
    np.testing.assert_array_equal(b.type_rows[3], [0, 2])
    np.testing.assert_array_equal(b.counts[0], cc)
    np.testing.assert_array_equal(b.counts[1], [1, 1])
    assert b.grid.fields["rows"][2] == grid.fields["rows"][5] - 2


def test_expand_scenarios_validates_chip():
    g = ConfigGrid.product()
    with pytest.raises(ValueError, match="counts"):
        hw_faults.expand_scenarios(g, [0, 1], [2], [])
    sc = hw_faults.FaultScenario(
        "d", (hw_faults.DegradedArray(5, rows_lost=1),))
    with pytest.raises(ValueError, match="out of range"):
        hw_faults.expand_scenarios(g, [0, 1], [2, 2], [sc])


def test_generators_are_seeded_and_bounded(grid):
    assert [s.name for s in
            hw_faults.all_single_core_failures([2, 0, 1])] == \
        ["core_loss_t0", "core_loss_t2"]
    a = hw_faults.random_degradations(7, grid, [0, 5], n_scenarios=6)
    b = hw_faults.random_degradations(7, grid, [0, 5], n_scenarios=6)
    assert [s.name for s in a] == [s.name for s in b]   # deterministic
    assert a != hw_faults.random_degradations(8, grid, [0, 5])
    for s in a:
        (ev,) = s.events
        ty = [0, 5][ev.type_idx]
        assert ev.rows_lost + ev.cols_lost >= 1
        assert ev.rows_lost <= grid.fields["rows"][ty] * 0.5
        assert ev.cols_lost <= grid.fields["cols"][ty] * 0.5


# ---------------------------------------------------------------------------
# batch_schedule_hetero: strict=False infeasibility + the scenario axis
# ---------------------------------------------------------------------------

def test_strict_default_still_raises():
    lat = np.ones((2, 2, 3))
    with pytest.raises(ValueError, match="strict=False"):
        partition.batch_schedule_hetero(lat, [[1, 1], [0, 0]])


def test_strict_false_reports_per_problem_infeasibility():
    rng = np.random.default_rng(0)
    lat = rng.uniform(0.1, 10.0, size=(3, 2, 4))
    counts = np.asarray([[1, 2], [0, 0], [2, 1]])
    res = partition.batch_schedule_hetero(
        lat, counts, strict=False, labels=["a", "b", "c"])
    assert res.feasible.tolist() == [True, False, True]
    assert np.isinf(res.bottleneck[1]) and (res.loads[1] == 0).all()
    for i in (0, 2):                      # feasible rows are unperturbed
        ref = partition.schedule_hetero_oracle(lat[i], counts[i])
        assert res.bottleneck[i] == ref["bottleneck"]
        res.schedule(i)                   # still constructible
    with pytest.raises(ValueError, match="b.*infeasible"):
        res.schedule(1)


def test_labels_length_validated():
    with pytest.raises(ValueError, match="labels"):
        partition.batch_schedule_hetero(
            np.ones((2, 1, 3)), [[1], [1]], strict=False, labels=["x"])


def test_4d_scenario_axis_equals_flattened():
    rng = np.random.default_rng(1)
    lat4 = rng.uniform(0.1, 10.0, size=(2, 3, 2, 5))
    counts3 = rng.integers(0, 3, size=(2, 3, 2))
    counts3[0, 0] = [1, 1]                # ≥ 1 feasible problem
    a = partition.batch_schedule_hetero(lat4, counts3, strict=False)
    b = partition.batch_schedule_hetero(
        lat4.reshape(6, 2, 5), counts3.reshape(6, 2), strict=False)
    np.testing.assert_array_equal(a.bottleneck, b.bottleneck)
    np.testing.assert_array_equal(a.feasible, b.feasible)
    np.testing.assert_array_equal(a.layer_type, b.layer_type)
    # 2-D counts broadcast across the scenario axis
    c = partition.batch_schedule_hetero(
        lat4, counts3[:, 0], strict=False)
    d = partition.batch_schedule_hetero(
        lat4.reshape(6, 2, 5), np.repeat(counts3[:, 0], 3, axis=0),
        strict=False)
    np.testing.assert_array_equal(c.bottleneck, d.bottleneck)


def _random_scenario_instance(rng):
    t = int(rng.integers(1, 4))
    n = int(rng.integers(1, 9))
    lat = rng.uniform(0.01, 100.0, size=(t, n))
    counts = rng.integers(0, 4, size=t)
    if counts.sum() == 0:
        counts[int(rng.integers(t))] = 1
    # random fault scenarios = perturbed (lat, counts) rows; always keep
    # the all-dead case in the mix so infeasibility round-trips
    S = int(rng.integers(2, 5))
    lat_s = np.repeat(lat[None], S, axis=0)
    cnt_s = np.repeat(counts[None], S, axis=0)
    for s in range(1, S):
        if rng.random() < 0.5:            # core loss
            cnt_s[s, int(rng.integers(t))] -= 1
        else:                             # degraded array: slower rows
            lat_s[s, int(rng.integers(t))] *= rng.uniform(1.0, 3.0)
    cnt_s = np.maximum(cnt_s, 0)
    if S > 2:
        cnt_s[S - 1] = 0                  # whole chip dead
    return lat_s, cnt_s


def _check_scenario_batch(lat_s, cnt_s, use_jax):
    res = partition.batch_schedule_hetero(lat_s[None], cnt_s[None],
                                          use_jax=use_jax, strict=False)
    for s in range(lat_s.shape[0]):
        if not (cnt_s[s] > 0).any():
            assert not res.feasible[s]
            assert np.isinf(res.bottleneck[s])
            continue
        ref = partition.schedule_hetero_oracle(lat_s[s], cnt_s[s])
        assert res.feasible[s]
        assert res.bottleneck[s] == ref["bottleneck"], (s, use_jax)


if _HAS_HYPOTHESIS:
    def _scenario_property(f):
        return settings(max_examples=80, deadline=None)(
            given(st.integers(0, 2**32 - 1), st.booleans())(f))
else:                                                  # pragma: no cover
    _scenario_property = _skip_property


@_scenario_property
def test_scenario_batch_matches_oracle_property(seed, use_jax):
    """Batched fault re-scheduling == the per-scenario oracle loop on
    random ≤(3 types × 8 layers) instances × random fault scenarios,
    numpy and jax backends — bit-exact, infeasible rows as +inf."""
    lat_s, cnt_s = _random_scenario_instance(np.random.default_rng(seed))
    _check_scenario_batch(lat_s, cnt_s, use_jax)


def test_scenario_batch_matches_oracle_seeded():
    """Non-hypothesis twin (always runs): 60 seeded instances."""
    rng = np.random.default_rng(42)
    for _ in range(60):
        lat_s, cnt_s = _random_scenario_instance(rng)
        for use_jax in (False, True):
            _check_scenario_batch(lat_s, cnt_s, use_jax)


def test_duplicated_degraded_row_tie_breaks_to_lower_type():
    """Regression: a degradation can make two type rows IDENTICAL — the
    per-layer argmin must still deterministically pick the lower type
    index (batch == oracle, and the schedule only uses type 0)."""
    lat = np.asarray([[2.0, 3.0, 4.0],
                      [2.0, 3.0, 4.0]])   # duplicated rows, exact ties
    counts = np.asarray([2, 2])
    for use_jax in (False, True):
        res = partition.batch_schedule_hetero([lat], [counts],
                                              use_jax=use_jax,
                                              strict=False)
        ref = partition.schedule_hetero_oracle(lat, counts)
        assert res.bottleneck[0] == ref["bottleneck"]
        assert (res.layer_type[0, :3] == 0).all()


# ---------------------------------------------------------------------------
# resilience_codesign
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def resil(grid, networks):
    return hetero.resilience_codesign(grid, networks, 4, max_types=2,
                                      pool_size=4,
                                      degradations=((2, 2),))


def test_resilience_front_contains_nominal_winner(resil):
    """The (nominal, worst-case) weak-dominance front must contain the
    nominal-only winner — the resilience view strictly ADDS information,
    it never loses the nominal choice."""
    assert resil.front[resil.best_nominal]
    assert resil.front[resil.best_robust]
    assert resil.nominal_score[resil.best_nominal] == \
        resil.nominal_score.min()
    # the robust pick's worst case is the best achievable
    assert resil.worst_score[resil.best_robust] == pytest.approx(
        resil.worst_score.min())
    # every front member is genuinely non-dominated
    n, w = resil.nominal_score, resil.worst_score
    for i in np.flatnonzero(resil.front):
        dominated = ((n <= n[i]) & (w <= w[i])
                     & ((n < n[i]) | (w < w[i]))).any()
        assert not dominated


def test_resilience_scenario_axis(resil):
    S = len(resil.scenario_names)
    assert resil.scenario_names[0] == "nominal"
    assert resil.valid.shape == (resil.n_chips, S)
    assert not resil.valid[:, 0].any()    # nominal is not a fault
    np.testing.assert_array_equal(resil.nominal_score, resil.scores[:, 0])
    # fault slots beyond a chip's type count are invalid for it
    for c, ty in enumerate(resil.chip_types):
        for s, nm in enumerate(resil.scenario_names[1:], start=1):
            slot = int(nm.split("slot")[1])
            assert resil.valid[c, s] == (slot < len(ty))
    # worst/expected reduce over the valid fault slots only
    fault = resil.valid.copy()
    want_worst = np.where(fault, resil.scores, -np.inf).max(axis=1)
    np.testing.assert_array_equal(resil.worst_score, want_worst)


def test_resilience_matches_per_scenario_oracle(grid, networks, resil):
    """Spot-check: the batched scenario solve is bit-exact against the
    per-(chip, network, scenario) oracle loop, rebuilt independently via
    the hw_faults expansion path."""
    probs = hetero.codesign_problems(grid, networks, 4, max_types=2,
                                     pool_size=4)
    lens = energymodel.network_layer_counts(networks)
    rng = np.random.default_rng(0)
    for c in rng.choice(resil.n_chips, size=min(4, resil.n_chips),
                        replace=False):
        ty, cn = resil.chip_types[c], resil.chip_counts[c]
        pool_rows = [probs.pool[p] for p in ty]
        scens = []
        for s, nm in enumerate(resil.scenario_names[1:], start=1):
            if not resil.valid[c, s]:
                continue
            slot = int(nm.split("slot")[1])
            if nm.startswith("core_loss"):
                scens.append((s, hw_faults.FaultScenario(
                    nm, (hw_faults.CoreFailure(slot),))))
            else:
                scens.append((s, hw_faults.FaultScenario(
                    nm, (hw_faults.DegradedArray(slot, 2, 2),))))
        b = hw_faults.expand_scenarios(grid, pool_rows, cn,
                                       [sc for _, sc in scens])
        e_l, t_l = energymodel.evaluate_networks(b.grid, networks,
                                                 per_layer=True)
        lat, cnt, nl, _en = hw_faults.scenario_problems(b, e_l, t_l, lens)
        n_net = len(networks)
        for k, (s, _sc) in enumerate([(0, None)] + scens):
            for j in range(n_net):
                i = k * n_net + j
                if not (cnt[i] > 0).any():
                    assert not resil.feasible[c, j, s]
                    continue
                ref = partition.schedule_hetero_oracle(
                    lat[i, :, :nl[i]], cnt[i])
                assert resil.bottleneck[c, j, s] == ref["bottleneck"], \
                    (c, j, s)


def test_resilience_all_types_dead_is_infeasible(grid, networks):
    """A 1-type 1-core chip dies entirely under core loss: reported as
    +inf, never raised."""
    res = hetero.resilience_codesign(grid, networks, 1, max_types=1,
                                     pool_size=2, degradations=())
    one_core = [c for c in range(res.n_chips)
                if sum(res.chip_counts[c]) == 1]
    assert one_core                        # m_cores=1 ⇒ all single-core
    for c in one_core:
        s = 1 + 0                          # core_loss@slot0
        assert res.valid[c, s]
        assert not res.feasible[c, :, s].any()
        assert np.isinf(res.scores[c, s])
        assert np.isinf(res.worst_score[c])


def test_frontier_with_strict_false_infeasible_chips(grid, networks):
    """strict=False infeasibility flows all the way through
    `ResilienceCoDesign.frontier()`: chips whose worst case is +inf (the
    fault kills every core) still render as frontier rows — reported,
    never raised — and the nominal winner keeps its front seat."""
    res = hetero.resilience_codesign(grid, networks, 1, max_types=1,
                                     pool_size=2, degradations=())
    assert np.isinf(res.worst_score).all()     # every chip 1-core, 1-type
    front = res.frontier()
    assert front                               # never empty
    chips = [c for c, _, _ in front]
    assert res.best_nominal in chips
    # best-nominal-first ordering, worst column all +inf
    noms = [n for _, n, _ in front]
    assert noms == sorted(noms)
    assert all(np.isinf(w) for _, _, w in front)
    assert front[0][1] == pytest.approx(res.nominal_score.min())
    # the infeasible schedule extraction still names the dead scenario
    # via the strict=False labels instead of crashing numerically
    s = 1                                      # core_loss@slot0
    assert not res.feasible[:, :, s].any()
    assert np.isinf(res.energy[:, :, s]).all()


def test_resilience_deadline_mode_saves_energy(grid, networks):
    """resilience_codesign(deadline=...) re-solves every (chip, net,
    scenario) cell with the energy-aware slack pass: energies never rise
    above the latency-only solve, moves are reported, and cells that
    cannot meet the deadline are +inf (not raised)."""
    base = hetero.resilience_codesign(grid, networks, 4, max_types=2,
                                      pool_size=4, degradations=((2, 2),))
    res = hetero.resilience_codesign(grid, networks, 4, max_types=2,
                                     pool_size=4, degradations=((2, 2),),
                                     deadline=3.0)
    assert res.deadline == 3.0 and base.deadline is None
    assert res.slack_moves is not None
    assert res.slack_moves.shape == res.energy.shape
    assert (res.slack_moves >= 0).all()
    feas = res.feasible
    # deadline-mode energy <= latency-only energy wherever both feasible
    both = feas & base.feasible
    assert (res.energy[both] <=
            base.energy[both] * (1.0 + 1e-9)).all()
    assert (res.slack_moves[both] > 0).any()   # slack actually used
    # the deadline binds: feasible cells meet it, the rest are +inf
    assert np.isinf(res.energy[~feas]).all()
    assert np.isinf(res.bottleneck[~feas]).all()
    # a crushing deadline kills everything — reported, never raised
    tight = hetero.resilience_codesign(grid, networks, 4, max_types=2,
                                       pool_size=4,
                                       degradations=((2, 2),),
                                       deadline=0.01)
    assert not tight.feasible.any()
    assert np.isinf(tight.scores).all()


# ---------------------------------------------------------------------------
# DSEService.fault_event
# ---------------------------------------------------------------------------

def _serve_chip(svc):
    svc.submit("best_chip", deadline=2.0)
    out, drained = svc.run_until_drained()
    assert drained and out[0].ok and out[0].answer["feasible"]
    return out[0].answer


def test_fault_event_reschedules_without_restart(grid, networks):
    clk = FakeClock()
    svc = DSEService(grid, networks, chunk_size=5, clock=clk,
                     sleep=clk.sleep)
    chip = _serve_chip(svc)
    sc = hw_faults.FaultScenario("t0_loss", (hw_faults.CoreFailure(0),))
    sub = svc.fault_event(chip["chip_types"], chip["chip_counts"], sc)
    assert sub.accepted
    (r,), drained = svc.run_until_drained()
    assert drained and r.ok and r.kind == "reschedule"
    a = r.answer
    assert a["scenario"] == "t0_loss"
    assert a["counts_after"][0] == chip["chip_counts"][0] - 1
    assert svc.stats["fault_events"] == 1
    assert svc.stats["reschedules"] == 1

    # the answer is bit-exact vs the direct expansion + oracle loop
    b = hw_faults.expand_scenarios(grid, chip["chip_types"],
                                   chip["chip_counts"], [sc])
    e_l, t_l = energymodel.evaluate_networks(b.grid, networks,
                                             per_layer=True)
    lens = energymodel.network_layer_counts(networks)
    lat, cnt, nl, _ = hw_faults.scenario_problems(b, e_l, t_l, lens)
    for j, nm in enumerate(NETS):
        i = len(NETS) + j                  # scenario row 1 = the fault
        d = a["networks"][nm]
        if not (cnt[i] > 0).any():
            assert not d["feasible"]
            continue
        ref = partition.schedule_hetero_oracle(lat[i, :, :nl[i]], cnt[i])
        assert d["bottleneck"] == ref["bottleneck"]
        nom = partition.schedule_hetero_oracle(
            lat[j, :, :nl[j]], cnt[j])
        assert d["overhead"] == pytest.approx(
            ref["bottleneck"] / nom["bottleneck"])


def test_fault_event_invalidates_cached_schedules(grid, networks):
    clk = FakeClock()
    svc = DSEService(grid, networks, chunk_size=5, clock=clk,
                     sleep=clk.sleep)
    chip = _serve_chip(svc)
    ct, cc = chip["chip_types"], chip["chip_counts"]
    sc = hw_faults.FaultScenario("t0_loss", (hw_faults.CoreFailure(0),))
    svc.submit("reschedule", chip_types=ct, chip_counts=cc, scenario=sc)
    svc.run_until_drained()
    assert svc.stats["resched_cache_misses"] == 1
    # same query again: served from cache
    svc.submit("reschedule", chip_types=ct, chip_counts=cc, scenario=sc)
    svc.run_until_drained()
    assert svc.stats["resched_cache_hits"] == 1
    # a fault event on that chip invalidates its cached schedules
    # (nominal + fault = 2 entries), so the re-query recomputes
    svc.fault_event(ct, cc, sc)
    assert svc.stats["schedule_invalidations"] == 2
    svc.run_until_drained()
    assert svc.stats["resched_cache_misses"] == 2
    assert svc.stats["resched_cache_hits"] == 1


def test_fault_event_chip_killed_still_answers(grid, networks):
    clk = FakeClock()
    svc = DSEService(grid, networks, chunk_size=5, clock=clk,
                     sleep=clk.sleep)
    chip = _serve_chip(svc)
    kill = hw_faults.FaultScenario("all_dead", tuple(
        hw_faults.CoreFailure(t, n=int(c))
        for t, c in enumerate(chip["chip_counts"]) if c))
    svc.fault_event(chip["chip_types"], chip["chip_counts"], kill)
    (r,), drained = svc.run_until_drained()
    assert drained and r.ok
    assert not r.answer["feasible"]
    assert all(np.isinf(d["bottleneck"]) and not d["feasible"]
               for d in r.answer["networks"].values())
    # the service is still alive and serving
    svc.submit("best_chip", deadline=2.0)
    (r2,), drained = svc.run_until_drained()
    assert drained and r2.ok


def test_reschedule_submit_validation(grid, networks):
    svc = DSEService(grid, networks, chunk_size=5)
    sc = hw_faults.FaultScenario("s", (hw_faults.CoreFailure(0),))
    with pytest.raises(ValueError, match="chip_types"):
        svc.submit("reschedule", scenario=sc)
    with pytest.raises(ValueError, match="FaultScenario"):
        svc.submit("reschedule", chip_types=[0], chip_counts=[2])
    with pytest.raises(ValueError, match="counts"):
        svc.submit("reschedule", chip_types=[0, 1], chip_counts=[2],
                   scenario=sc)
    with pytest.raises(ValueError, match="out of range"):
        svc.submit("reschedule", chip_types=[grid.n], chip_counts=[2],
                   scenario=sc)
    with pytest.raises(ValueError, match="out of range"):
        svc.submit("reschedule", chip_types=[0], chip_counts=[2],
                   scenario=hw_faults.FaultScenario(
                       "bad", (hw_faults.CoreFailure(3),)))


def test_reschedule_queries_coalesce(grid, networks):
    clk = FakeClock()
    svc = DSEService(grid, networks, chunk_size=5, clock=clk,
                     sleep=clk.sleep)
    chip = _serve_chip(svc)
    ct, cc = chip["chip_types"], chip["chip_counts"]
    for t in range(len(ct)):
        svc.submit("reschedule", chip_types=ct, chip_counts=cc,
                   scenario=hw_faults.FaultScenario(
                       f"loss{t}", (hw_faults.CoreFailure(t),)))
    before = svc.stats["coalesced_batches"]
    out = svc.step()                       # ONE step serves the family
    assert len(out) == len(ct) and all(r.ok for r in out)
    assert svc.stats["coalesced_batches"] == before + (len(ct) > 1)


@pytest.mark.parametrize("seed", SEEDS)
def test_fault_event_survives_chunk_chaos(grid, networks, seed):
    """Chaos replay: chunk faults rain on the streamed sweep, then a
    hardware fault event forces a re-schedule — the service answers
    everything without a restart."""
    clk = FakeClock()
    svc = DSEService(grid, networks, chunk_size=5, max_retries=30,
                     backoff_s=1e-4, clock=clk, sleep=clk.sleep)
    n_chunks = -(-grid.n // 5)
    plan = FaultPlan.random(seed, n_chunks, p_fail=0.3, p_corrupt=0.2)
    with inject_chunk_faults(plan):
        chip = _serve_chip(svc)
        scen = hw_faults.all_single_core_failures(
            chip["chip_counts"])[seed % len(chip["chip_counts"])]
        svc.fault_event(chip["chip_types"], chip["chip_counts"], scen)
        out, drained = svc.run_until_drained()
    assert drained and all(r.ok for r in out)
    assert svc.stats["reschedules"] == 1
    assert svc.health()["errors"] == 0
