"""Fused Pallas count-terms kernel: parity against the pure-jnp oracle
(`ref.py`), the existing jax engine, and the numpy reference — plus the
backend auto-fallback contract and a hypothesis property sweep over random
layer/config rows."""

import numpy as np
import pytest

from repro.core import accelerator, energymodel, topology
from repro.kernels.count_terms import (count_term_layers,
                                       count_term_layers_ref,
                                       count_term_sums, count_term_sums_ref)
from repro.kernels.count_terms.kernel import CFG_COLUMNS, LAYER_FIELDS

NETS = ("AlexNet", "VGG16", "MobileNet")


@pytest.fixture(scope="module")
def networks():
    return {n: topology.get_network(n) for n in NETS}


def _kernel_inputs(grid, networks):
    """Grid + networks → the engine operands the kernel consumes."""
    lay, segments = energymodel._stack_networks(networks)
    lay = {k: v[None, :] for k, v in lay.items()}
    cfgs = energymodel._cfg_struct_from_grid(np, grid)
    cfg_u, _ = energymodel._dedup_count_rows(cfgs)
    cfg_u = {k: v[:, None] for k, v in cfg_u.items()}
    return cfg_u, lay, segments


def _pallas_vs_ref(cfg_u, lay, segments, rtol=1e-12):
    from jax.experimental import enable_x64
    with enable_x64():
        ref = np.asarray(count_term_sums_ref(cfg_u, lay, segments))
        out = np.stack([np.asarray(o)
                        for o in count_term_sums(cfg_u, lay, segments)])
    assert out.shape == ref.shape
    np.testing.assert_allclose(out, ref, rtol=rtol, atol=0.0)


def test_pallas_matches_ref_paper_grid(networks):
    """Interpret-mode kernel ≡ the pure-jnp oracle on the 150-pt space."""
    _pallas_vs_ref(*_kernel_inputs(accelerator.ConfigGrid.product(),
                                   networks))


def test_pallas_matches_ref_odd_blocks(networks):
    """Unique-row counts that don't divide the block sizes exercise the
    edge-padding path (row-0 repeats + zero segment columns)."""
    grid = accelerator.ConfigGrid.product(
        arrays=((12, 14), (16, 16), (64, 64)), gb_psum_kb=(13, 54, 216),
        gb_ifmap_kb=(27,))
    _pallas_vs_ref(*_kernel_inputs(grid, networks))


def test_per_layer_kernel_matches_ref(networks):
    """The segment-matmul-free per-layer variant ≡ the raw [14, n_u, L]
    term stack, and summing its segments reproduces count_term_sums."""
    from jax.experimental import enable_x64
    cfg_u, lay, segments = _kernel_inputs(
        accelerator.ConfigGrid.product(
            arrays=((12, 14), (16, 16), (64, 64)),
            gb_psum_kb=(13, 54, 216), gb_ifmap_kb=(27,)), networks)
    with enable_x64():
        ref = np.asarray(count_term_layers_ref(cfg_u, lay))
        out = np.stack([np.asarray(o)
                        for o in count_term_layers(cfg_u, lay)])
        sums = np.stack([np.asarray(o)
                         for o in count_term_sums(cfg_u, lay, segments)])
    assert out.shape == ref.shape
    np.testing.assert_allclose(out, ref, rtol=1e-12, atol=0.0)
    seg_sums = np.stack([out[..., a:b].sum(-1) for a, b in segments],
                        axis=-1)
    np.testing.assert_allclose(seg_sums, sums, rtol=1e-12)


def test_per_layer_kernel_odd_blocks(networks):
    """Layer/row paddings of the per-layer kernel slice off cleanly."""
    from jax.experimental import enable_x64
    grid = accelerator.ConfigGrid.product(
        arrays=((16, 16),), gb_psum_kb=(13, 27, 54), gb_ifmap_kb=(27, 54))
    cfg_u, lay, _ = _kernel_inputs(grid, {"AlexNet":
                                          networks["AlexNet"]})
    with enable_x64():
        ref = np.asarray(count_term_layers_ref(cfg_u, lay))
        out = np.stack([np.asarray(o)
                        for o in count_term_layers(cfg_u, lay,
                                                   block_u=4, block_l=8)])
    np.testing.assert_allclose(out, ref, rtol=1e-12, atol=0.0)


def test_pallas_backend_matches_jax_engine_5400_subsample(networks):
    """End-to-end backend parity on a subsample of the extended 5,400-pt
    space: pallas vs jax vs numpy, all within the bench guardrail (1e-6 —
    observed: machine eps)."""
    grid = accelerator.extended_grid().take(np.arange(0, 5400, 37))
    e_p, t_p = energymodel.evaluate_networks(grid, networks,
                                             backend="pallas")
    e_j, t_j = energymodel.evaluate_networks(grid, networks, backend="jax")
    e_n, t_n = energymodel.evaluate_networks(grid, networks,
                                             backend="numpy")
    np.testing.assert_allclose(e_p, e_j, rtol=1e-9)
    np.testing.assert_allclose(t_p, t_j, rtol=1e-9)
    np.testing.assert_allclose(e_p, e_n, rtol=1e-6)
    np.testing.assert_allclose(t_p, t_n, rtol=1e-6)


def test_pallas_routes_through_chunked_sharded_stream(networks):
    """backend="pallas" must flow through every engine path: chunked,
    sharded (1-device mesh degenerates), and streaming reductions."""
    grid = accelerator.ConfigGrid.product()
    e0, t0 = energymodel.evaluate_networks(grid, networks, use_jax=False)
    for kw in (dict(chunk_size=64), dict(shard=True),
               dict(shard=True, chunk_size=64)):
        e1, t1 = energymodel.evaluate_networks(grid, networks,
                                               backend="pallas", **kw)
        np.testing.assert_allclose(e1, e0, rtol=1e-9)
        np.testing.assert_allclose(t1, t0, rtol=1e-9)
        assert energymodel.last_backend() == "pallas"
    sr = energymodel.stream_networks(grid, networks, chunk_size=64,
                                     backend="pallas")
    edp = e0 * t0
    np.testing.assert_allclose(sr.min_metric, edp.min(0), rtol=1e-9)
    assert np.array_equal(sr.argmin, edp.argmin(0))


def test_backend_resolution_and_fallback(monkeypatch):
    assert energymodel.resolve_backend("pallas") == "pallas"
    assert energymodel.resolve_backend("numpy") == "numpy"
    assert energymodel.resolve_backend(None, True) == "jax"
    assert energymodel.resolve_backend(None, False) == "numpy"
    with pytest.raises(ValueError):
        energymodel.resolve_backend("tpu")
    monkeypatch.setattr(energymodel, "pallas_available", lambda: False)
    assert energymodel.resolve_backend("pallas") == "jax"
    monkeypatch.setattr(energymodel, "jax_available", lambda: False)
    assert energymodel.resolve_backend("pallas") == "numpy"
    assert energymodel.resolve_backend(None) == "numpy"


def test_kernel_column_orders_match_engine():
    """The kernel's stacked operand orders must track the engine structs —
    a silent reorder would compute valid-looking garbage."""
    assert CFG_COLUMNS == energymodel._COUNT_COLUMNS
    from repro.core import rs_mapping
    lay = rs_mapping.layer_struct(
        np, [l for l in topology.get_network("AlexNet")
             if l.kind != "input"])
    assert tuple(lay.keys()) == LAYER_FIELDS


# ---------------------------------------------------------------------------
# hypothesis property sweep: random layer and config rows.  Guarded per-test
# (not module-level importorskip) so the parity tests above always run.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    _HAS_HYPOTHESIS = False


def _random_layer_rows(draw, n_lay):
    dims = st.integers(min_value=1, max_value=96)
    rows = {k: [] for k in LAYER_FIELDS}
    for _ in range(n_lay):
        c, m, k, s = (draw(dims), draw(dims),
                      draw(st.sampled_from([1, 3, 5, 7, 11])),
                      draw(st.sampled_from([1, 2])))
        ox = oy = max(1, draw(dims) // s)
        ix, iy = (ox - 1) * s + k, (oy - 1) * s + k
        kind = draw(st.sampled_from(["conv", "dw", "pool", "fc"]))
        is_acc = kind in ("conv", "fc")
        c_out = m if is_acc else c
        row = dict(
            c_ch=c, m=c_out, ky=k, kx=k, stride=s, ix=ix, iy=iy,
            oy=oy, ox=ox,
            macs=float(c * c_out * k * k * ox * oy),
            weight_words=float(c * c_out * k * k),
            ifmap_words=float(c * ix * iy),
            ofmap_words=float(c_out * ox * oy),
            is_acc=float(is_acc), is_dw=float(kind == "dw"),
            is_pool=float(kind == "pool"))
        for kk, v in row.items():
            rows[kk].append(float(v))
    return {k: np.asarray(v, dtype=np.float64)[None, :]
            for k, v in rows.items()}


if _HAS_HYPOTHESIS:
    def _property(f):
        return settings(max_examples=20, deadline=None)(
            given(st.data())(f))
else:                                                  # pragma: no cover
    _property = pytest.mark.skip(
        reason="property test needs hypothesis "
        "(pip install -r requirements-dev.txt)")


@_property
def test_pallas_property_random_rows(data):
    """Random (config rows × layer rows × segment splits): the fused
    kernel agrees with the oracle wherever the oracle is finite."""
    draw = data.draw
    n_u = draw(st.integers(min_value=1, max_value=9))
    n_lay = draw(st.integers(min_value=1, max_value=12))
    lay = _random_layer_rows(draw, n_lay)

    word_sizes = st.sampled_from([16.0, 64.0, 512.0, 4096.0, 110592.0])
    cfg_u = {
        "rows": st.sampled_from([8.0, 12.0, 16.0, 32.0, 64.0]),
        "cols": st.sampled_from([8.0, 14.0, 16.0, 32.0, 64.0]),
        "gb_ifmap_words": word_sizes, "gb_psum_words": word_sizes,
        "rf_ifmap_words": st.just(12.0),
        "rf_weight_words": st.sampled_from([96.0, 224.0]),
        "rf_psum_words": st.sampled_from([16.0, 24.0]),
    }
    cfg_u = {k: np.asarray([draw(s) for _ in range(n_u)],
                           dtype=np.float64)[:, None]
             for k, s in cfg_u.items()}

    cut = draw(st.integers(min_value=0, max_value=n_lay))
    segments = ((0, cut), (cut, n_lay)) if 0 < cut < n_lay \
        else ((0, n_lay),)
    _pallas_vs_ref(cfg_u, lay, segments, rtol=1e-10)
