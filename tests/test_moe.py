"""MoE dispatch/combine invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe
from repro.models import params as P

KEY = jax.random.key(11)


def _cfg(**kw):
    base = get_config("qwen2-moe-a2.7b").smoke()
    return dataclasses.replace(base, **kw)


def test_router_topk_weights_normalised():
    cfg = _cfg()
    p = P.init_tree(moe.moe_spec(cfg), KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.bfloat16)
    ids, w = moe.route(p, cfg, x)
    assert ids.shape == (2, 16, cfg.top_k)
    np.testing.assert_allclose(np.asarray(w.sum(-1), np.float32), 1.0,
                               atol=2e-2)
    assert int(ids.max()) < cfg.n_experts


def test_dispatch_slots_consistent():
    cfg = _cfg()
    ids = jax.random.randint(KEY, (2, 16, cfg.top_k), 0, cfg.n_experts)
    cap = moe.capacity(cfg, 16)
    tok4slot, keep, slot_of = moe.dispatch_plan(cfg, ids, cap)
    assert tok4slot.shape == (2, cfg.n_experts, cap)
    # every kept (token, k) occupies the slot that points back at it
    t4s = np.asarray(tok4slot)
    for b in range(2):
        for t in range(16):
            for k in range(cfg.top_k):
                if bool(keep[b, t, k]):
                    e = int(ids[b, t, k])
                    s = int(slot_of[b, t, k])
                    assert t4s[b, e, s] == t


def test_capacity_drops_overflow():
    cfg = _cfg(capacity_factor=0.25)          # tiny capacity forces drops
    ids = jnp.zeros((1, 64, cfg.top_k), jnp.int32)   # all to expert 0
    cap = moe.capacity(cfg, 64)
    _, keep, _ = moe.dispatch_plan(cfg, ids, cap)
    assert int(keep.sum()) == cap             # only cap assignments survive


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    p = P.init_tree(moe.moe_spec(cfg), KEY)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model), jnp.bfloat16)
    y = moe.apply_moe(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))


def test_load_balance_loss_range():
    cfg = _cfg()
    p = P.init_tree(moe.moe_spec(cfg), KEY)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model), jnp.bfloat16)
    lb = float(moe.load_balance_loss(p, cfg, x))
    # ≥ top_k for a perfectly balanced router; finite and positive always
    assert 0.0 < lb < 10.0 * cfg.top_k


def test_dense_residual_and_shared_paths():
    cfg = _cfg(moe_dense_residual=True, dense_residual_ff=32)
    p = P.init_tree(moe.moe_spec(cfg), KEY)
    assert "dense" in p and "shared" in p
    x = jax.random.normal(KEY, (1, 8, cfg.d_model), jnp.bfloat16)
    y = moe.apply_moe(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
