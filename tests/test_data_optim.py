"""Data pipeline determinism/sharding + optimizer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataPipeline, SyntheticLM
from repro.optim import adafactor, adamw
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim import compress


def test_data_deterministic_replay():
    src = SyntheticLM(1000, seed=3)
    a = src.sample(step=5, index=2, seq_len=64)
    b = src.sample(step=5, index=2, seq_len=64)
    c = src.sample(step=6, index=2, seq_len=64)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_data_host_sharding_disjoint():
    src = SyntheticLM(1000, seed=0)
    p0 = DataPipeline(src, global_batch=8, seq_len=16, host_id=0,
                      num_hosts=2)
    p1 = DataPipeline(src, global_batch=8, seq_len=16, host_id=1,
                      num_hosts=2)
    b0 = p0._make_batch(0)["tokens"]
    b1 = p1._make_batch(0)["tokens"]
    p0.close(); p1.close()
    assert b0.shape == (4, 16)
    assert not np.array_equal(b0, b1)
    # resumability: state round-trip
    assert p0.state()["num_hosts"] == 2


def test_data_prefetch_iterates():
    src = SyntheticLM(100, seed=1)
    p = DataPipeline(src, global_batch=4, seq_len=8)
    batches = [next(p) for _ in range(3)]
    p.close()
    assert all(b["tokens"].shape == (4, 8) for b in batches)


def _quadratic_descent(opt):
    target = jnp.asarray([1.0, -2.0, 3.0] * 50, jnp.float32).reshape(10, 15)
    params = {"w": jnp.zeros((10, 15), jnp.bfloat16)}
    state = opt.init(params)

    def loss(p):
        return jnp.mean((p["w"].astype(jnp.float32) - target) ** 2)

    l0 = loss(params)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, step_lr=0.1)
    return float(l0), float(loss(params))


def test_adamw_descends():
    l0, l1 = _quadratic_descent(adamw(keep_master=True))
    assert l1 < 0.2 * l0


def test_adafactor_descends():
    l0, l1 = _quadratic_descent(adafactor(min_dim_factored=8))
    assert l1 < 0.5 * l0


def test_clip_bounds_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 100.0


def test_ef_int8_roundtrip_error_feedback():
    g = {"w": jnp.linspace(-1, 1, 256).reshape(16, 16)}
    qs, ss, res = compress.ef_int8_compress(g, None)
    deq = compress.ef_int8_decompress(qs, ss)
    err = float(jnp.max(jnp.abs(deq["w"] - g["w"])))
    assert err < 1.0 / 127 + 1e-6
    # residual carries exactly the quantisation error
    np.testing.assert_allclose(np.asarray(res["w"]),
                               np.asarray(g["w"] - deq["w"]), atol=1e-6)
