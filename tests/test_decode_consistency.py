"""Decode-vs-forward consistency: token-by-token decoding from an empty
cache must reproduce the training forward's logits (teacher forcing)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model_zoo as Z
from repro.models import params as P

pytestmark = pytest.mark.slow      # full-model end-to-end runs

KEY = jax.random.key(7)
T = 12


def _decode_all(cfg, params, tokens, cache):
    outs = []
    for i in range(tokens.shape[1]):
        logits, cache = Z.decode_step(params, cfg, tokens[:, i:i + 1], cache)
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "phi3-mini-3.8b",
                                  "stablelm-1.6b", "qwen2-moe-a2.7b"])
def test_transformer_decode_matches_forward(arch):
    cfg = get_config(arch).smoke()
    params = Z.init(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, T), 0, cfg.vocab, jnp.int32)
    full = Z.forward(params, cfg, {"tokens": tokens})
    cache = P.init_tree(Z.cache_spec(cfg, 2, T + 4), KEY)
    dec = _decode_all(cfg, params, tokens, cache)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        rtol=0.12, atol=0.12)           # bf16 accumulation-order tolerance


def test_mamba2_decode_matches_forward():
    cfg = get_config("mamba2-2.7b").smoke()
    params = Z.init(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab, jnp.int32)
    full = Z.forward(params, cfg, {"tokens": tokens})
    cache = P.init_tree(Z.cache_spec(cfg, 2, 8), KEY)
    dec = _decode_all(cfg, params, tokens, cache)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        rtol=0.15, atol=0.15)


def test_recurrentgemma_decode_matches_forward():
    cfg = get_config("recurrentgemma-9b").smoke()
    params = Z.init(cfg, KEY)
    t = min(8, cfg.attn_window - 1)      # exact while within the window
    tokens = jax.random.randint(KEY, (2, t), 0, cfg.vocab, jnp.int32)
    full = Z.forward(params, cfg, {"tokens": tokens})
    cache = P.init_tree(Z.cache_spec(cfg, 2, cfg.attn_window), KEY)
    dec = _decode_all(cfg, params, tokens, cache)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        rtol=0.15, atol=0.15)


def test_whisper_decode_matches_forward():
    cfg = get_config("whisper-base").smoke()
    params = Z.init(cfg, KEY)
    frames = jax.random.normal(
        KEY, (2, cfg.n_audio_frames, cfg.d_model)).astype(jnp.bfloat16)
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab, jnp.int32)
    full = Z.forward(params, cfg, {"tokens": tokens, "frames": frames})
    from repro.models import whisper
    cache = P.init_tree(Z.cache_spec(cfg, 2, 12), KEY)
    ck, cv = whisper.init_cross_cache(params, cfg, frames)
    cache = dict(cache, cross_k=ck, cross_v=cv)
    dec = _decode_all(cfg, params, tokens, cache)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        rtol=0.15, atol=0.15)
