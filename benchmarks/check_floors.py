"""Assert BENCH_dse speedup floors against committed baselines.

    PYTHONPATH=src python -m benchmarks.check_floors \
        [--quick-json BENCH_dse.quick.json] [--committed BENCH_dse.json] \
        [--floors benchmarks/floors.json]

CI's fast job runs this right after ``benchmarks.run --quick``: the
committed ``BENCH_dse.json`` trajectory file must keep meeting the
full-run floors (so a perf-regressing PR fails the build instead of the
regression merely drifting in the JSON), and the freshly regenerated
``BENCH_dse.quick.json`` must meet the conservative quick floors and
every parity ceiling.  Floors live in ``benchmarks/floors.json``
(documented in docs/bench_schema.md).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _level(payload: dict, name: str) -> dict | None:
    for lv in payload.get("levels", []):
        if lv.get("name") == name:
            return lv
    return None


#: Top-level payload sections that carry their own floor dicts (the
#: per-grid-size ``levels`` are handled separately by name).
FLOOR_SECTIONS = ("codesign", "codesign_mega", "slack")


def check_payload(payload: dict, floors: dict, label: str) -> list:
    """→ list of violation strings for one payload vs one floor set."""
    problems = []
    for name, want in floors.get("levels", {}).items():
        lv = _level(payload, name)
        if lv is None:
            problems.append(f"{label}: level {name!r} missing")
            continue
        for key, floor in want.items():
            got = lv.get(key)
            if got is None or got < floor:
                problems.append(
                    f"{label}: level {name} {key}={got} < floor {floor}")
    for section in FLOOR_SECTIONS:
        sec = payload.get(section) or {}
        for key, floor in floors.get(section, {}).items():
            got = sec.get(key)
            if got is None or got < floor:
                problems.append(
                    f"{label}: {section} {key}={got} < floor {floor}")
    return problems


def check_parity(payload: dict, ceiling: float, label: str) -> list:
    """Every ``max_rel_err_*`` / ``max_rel_diff_*`` in the payload must
    sit under the ceiling (None = backend unavailable, skipped)."""
    problems = []

    def scan(d: dict, where: str):
        for k, v in d.items():
            if (k.startswith("max_rel_err") or k.startswith("max_rel_diff")) \
                    and v is not None and v > ceiling:
                problems.append(f"{label}: {where}.{k}={v:.2e} > {ceiling}")

    for lv in payload.get("levels", []):
        scan(lv, f"level {lv.get('name')}")
    scan(payload.get("partition") or {}, "partition")
    scan(payload.get("codesign") or {}, "codesign")
    scan(payload.get("codesign_mega") or {}, "codesign_mega")
    scan(payload.get("slack") or {}, "slack")
    return problems


def _dotted(payload: dict, path: str):
    cur = payload
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def check_serve(payload: dict, bounds: dict, label: str) -> list:
    """BENCH_serve guardrails: dotted-path keys are floors
    (``got < bound`` fails); keys with a ``_max`` suffix are CEILINGS on
    the stripped path (``got > bound`` fails) — e.g.
    ``recovery.recovery_ratio_max`` caps the crash-recovery tax."""
    problems = []
    for key, bound in bounds.items():
        if key.endswith("_max"):
            got = _dotted(payload, key[:-len("_max")])
            if got is None or got > bound:
                problems.append(
                    f"{label}: {key[:-4]}={got} > ceiling {bound}")
        else:
            got = _dotted(payload, key)
            if got is None or got < bound:
                problems.append(f"{label}: {key}={got} < floor {bound}")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick-json", default="BENCH_dse.quick.json")
    ap.add_argument("--committed", default="BENCH_dse.json")
    ap.add_argument("--serve-quick-json", default="BENCH_serve.quick.json")
    ap.add_argument("--serve-committed", default="BENCH_serve.json")
    ap.add_argument("--resil-quick-json", default="BENCH_resil.quick.json")
    ap.add_argument("--resil-committed", default="BENCH_resil.json")
    ap.add_argument("--floors", default="benchmarks/floors.json")
    ap.add_argument("--report", default=None,
                    help="also write the pass/fail lines to this file "
                         "(uploaded as a CI artifact)")
    args = ap.parse_args()

    floors = json.loads(Path(args.floors).read_text())
    ceiling = float(floors.get("parity_ceiling", 1e-6))
    problems = []

    committed = json.loads(Path(args.committed).read_text())
    problems += check_payload(committed, floors["committed"], "committed")
    problems += check_parity(committed, ceiling, "committed")

    quick_path = Path(args.quick_json)
    if quick_path.exists():
        quick = json.loads(quick_path.read_text())
        problems += check_payload(quick, floors["quick"], "quick")
        problems += check_parity(quick, ceiling, "quick")
    else:
        problems.append(f"quick payload {quick_path} not found "
                        "(run `python -m benchmarks.run --quick` first)")

    serve_floors = floors.get("serve", {})
    if serve_floors:
        serve = json.loads(Path(args.serve_committed).read_text())
        problems += check_serve(serve, serve_floors.get("committed", {}),
                                "serve committed")
        serve_quick_path = Path(args.serve_quick_json)
        if serve_quick_path.exists():
            serve_quick = json.loads(serve_quick_path.read_text())
            problems += check_serve(serve_quick,
                                    serve_floors.get("quick", {}),
                                    "serve quick")
        else:
            problems.append(
                f"serve quick payload {serve_quick_path} not found "
                "(run `python -m benchmarks.serve_bench --quick` first)")

    resil_floors = floors.get("resil", {})
    if resil_floors:
        resil = json.loads(Path(args.resil_committed).read_text())
        problems += check_serve(resil, resil_floors.get("committed", {}),
                                "resil committed")
        resil_quick_path = Path(args.resil_quick_json)
        if resil_quick_path.exists():
            resil_quick = json.loads(resil_quick_path.read_text())
            problems += check_serve(resil_quick,
                                    resil_floors.get("quick", {}),
                                    "resil quick")
        else:
            problems.append(
                f"resil quick payload {resil_quick_path} not found "
                "(run `python -m benchmarks.resil_bench --quick` first)")

    lines = ([f"FLOOR CHECK FAILED: {p}" for p in problems]
             or ["floor checks passed "
                 f"(committed={args.committed}, quick={args.quick_json})"])
    if args.report:
        Path(args.report).write_text("\n".join(lines) + "\n")
    if problems:
        for line in lines:
            print(line, file=sys.stderr)
        raise SystemExit(1)
    print(lines[0])


if __name__ == "__main__":
    main()
