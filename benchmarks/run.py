"""Benchmark harness: one function per paper table/figure + the TPU
roofline/autoshard analyses.  Prints ``name,us_per_call,derived`` CSV rows
and writes the full tables to experiments/tables/*.csv.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import csv
import json
import os
import resource
import sys
import time
from pathlib import Path

# One XLA host device per CPU core (capped), BEFORE anything imports jax —
# the backend locks the device count on first init (same pattern as
# repro/launch/dryrun.py).  This gives the sharded engine paths a device
# axis to spread the config dimension over.
_N_DEV = max(1, min(os.cpu_count() or 1, 8))
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_N_DEV}")

import numpy as np

from repro.core import (accelerator, dse, energymodel, hetero, partition,
                        rs_mapping, topology)
from repro.core import autoshard
from repro.core.tpu_costmodel import ShardingPolicy, step_time


def _enable_persistent_cache() -> dict:
    """Opt-in JAX persistent compilation cache (REPRO_JAX_CACHE_DIR).

    Cuts the 7–14.5 s per-level cold compiles on repeat runs/CI by
    serving XLA executables from disk.  NOTE this does NOT make
    ``jit_cold_cache_hit`` true — that field reports the in-process
    TRACE cache (a fresh process always retraces); the persistent cache
    only shortens the compile underneath, visible as a lower
    ``jit_cold_s``.  The payload records it separately so cold numbers
    are never misread (see docs/bench_schema.md)."""
    cache_dir = os.environ.get("REPRO_JAX_CACHE_DIR")
    info = dict(enabled=False, dir=cache_dir or None)
    if not cache_dir:
        return info
    try:
        import jax
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # cache everything — the engine's kernels are many small programs
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        info["enabled"] = True
    except Exception as exc:               # pragma: no cover - version skew
        info["error"] = f"{type(exc).__name__}: {exc}"
    return info

OUT = Path("experiments/tables")
BENCH_DSE_JSON = Path("BENCH_dse.json")
BENCH_DSE_QUICK_JSON = Path("BENCH_dse.quick.json")

#: Chunk size of the streaming/mega paths: multiples of the mega grid's
#: noc-innermost axis keep per-chunk dedup aligned with the global dedup.
MEGA_CHUNK = 9800

PAPER_NETS = list(topology.NETWORKS)
QUICK_NETS = ["AlexNet", "VGG16", "GoogleNet", "ResNet50", "MobileNetV2",
              "Xception"]


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _write(name, header, rows):
    OUT.mkdir(parents=True, exist_ok=True)
    with open(OUT / f"{name}.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)


def _sweeps(nets):
    # one batched jit call: every network × the whole grid
    return dse.sweep_networks({n: topology.get_network(n) for n in nets})


# ---------------------------------------------------------------------------
# DSE engine scaling: numpy-per-config (the seed implementation) vs the
# batched jit engine, at 150 / 1,350 / 5,400 grid points.  Results land in
# BENCH_dse.json (machine-readable) so future PRs can track the trajectory.
# ---------------------------------------------------------------------------

def _seed_numpy_sweep(layers, configs):
    """The seed's design-space loop, verbatim: one AcceleratorConfig object
    per grid point, per-config numpy struct rows, full [n_cfg, n_layer]
    energy math summed at the end.  Kept here as the reference baseline the
    batched engine is measured (and parity-checked) against."""
    compute = [l for l in layers if l.kind != "input"]
    lay = rs_mapping.layer_struct(np, compute)
    lay = {k: np.asarray(v, dtype=np.float64)[None, :]
           for k, v in lay.items()}
    cfg_rows = [energymodel._cfg_struct(np, c) for c in configs]
    cfgs = {k: np.stack([np.float64(c[k]) for c in cfg_rows])[:, None]
            for k in cfg_rows[0]}
    ct = energymodel._counts(np, cfgs, lay)
    el = energymodel._energy_latency(np, cfgs, lay, ct)
    return el["energy"].sum(-1), el["latency"].sum(-1)


def _dse_scale_levels(quick: bool):
    paper = dict(arrays=accelerator.ARRAY_SIZES,
                 gb_psum_kb=accelerator.GB_SIZES_KB,
                 gb_ifmap_kb=accelerator.GB_SIZES_KB)
    levels = [("paper_150", accelerator.ConfigGrid.product(**paper))]
    if not quick:        # quick: one smoke level, no extra cold compiles
        levels += [
            ("extended_1350", accelerator.ConfigGrid.product(
                **paper, rf_psum_words=accelerator.RF_PSUM_SIZES,
                noc_words_per_cycle=accelerator.NOC_WIDTHS)),
            ("extended_5400", accelerator.extended_grid()),
        ]
    return levels


def _warm_min(fn, reps: int = 3) -> float:
    """Minimum wall time over ``reps`` runs, after ONE untimed pre-warm
    call: the pre-warm absorbs trace/dispatch-cache population, so the
    timed passes measure the steady state (the seed mixed the first
    dispatch-cache miss into its warm number)."""
    fn()
    return min(_timed(fn)[1] / 1e6 for _ in range(reps))


def _rss_peak_mb() -> float:
    """Process-lifetime RSS high-water mark (includes earlier levels —
    a conservative upper bound on the chunked path's footprint)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _rss_now_mb() -> float:
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return float(line.split()[1]) / 1024.0
    except OSError:                                    # pragma: no cover
        pass
    return float("nan")                                # pragma: no cover


def _pallas_columns(grid, nets, e_j, t_j, chunk: int | None = None) -> dict:
    """Timing + parity of the fused Pallas count-terms backend against the
    jax engine output ``(e_j, t_j)`` on the same grid.  Returns the v3
    ``pallas_*`` level columns (None-valued when Pallas is unavailable —
    the schema keeps the keys so consumers never branch on presence)."""
    if not energymodel.pallas_available():              # pragma: no cover
        return dict(backend_pallas=False, pallas_warm_s=None,
                    max_rel_err_pallas_energy=None,
                    max_rel_err_pallas_latency=None)
    kw = dict(backend="pallas")
    if chunk is not None:
        kw["chunk_size"] = chunk
    # the parity pass doubles as the untimed pre-warm (traces + dispatch
    # caches populated), so the timed reps measure the steady state
    e_p, t_p = energymodel.evaluate_networks(grid, nets, **kw)
    warm_s = min(
        _timed(lambda: energymodel.evaluate_networks(grid, nets,
                                                     **kw))[1] / 1e6
        for _ in range(2))
    return dict(
        backend_pallas=True, pallas_warm_s=round(warm_s, 4),
        max_rel_err_pallas_energy=float(np.max(np.abs(e_p - e_j) / e_j)),
        max_rel_err_pallas_latency=float(np.max(np.abs(t_p - t_j) / t_j)))


def _pallas_txt(level: dict) -> str:
    """Human-readable pallas clause for the CSV derived column."""
    if level.get("pallas_warm_s") is None:
        return "pallas n/a"
    perr = max(level["max_rel_err_pallas_energy"],
               level["max_rel_err_pallas_latency"])
    return f"pallas {level['pallas_warm_s']:.2f}s (err<={perr:.1e})"


def bench_dse_scale(quick: bool = False) -> list:
    nets = {n: topology.get_network(n) for n in topology.NETWORKS}
    use_jax = dse._use_jax_default()
    results = []
    for name, grid in _dse_scale_levels(quick):
        # seed path: per-network numpy loop over per-point config objects.
        # (Objects built once per level — the seed rebuilt them per network,
        # so this baseline is conservative.)
        configs = [grid.config_at(i) for i in range(grid.n)]
        t0 = time.perf_counter()
        e_np = np.empty((grid.n, len(nets)))
        t_np = np.empty((grid.n, len(nets)))
        for j, layers in enumerate(nets.values()):
            e_np[:, j], t_np[:, j] = _seed_numpy_sweep(layers, configs)
        numpy_s = time.perf_counter() - t0

        # batched jit engine: "cold" is the first call at this level
        # (jit_cold_cache_hit records whether an earlier same-shape call
        # had already compiled it); the warm passes run behind an untimed
        # pre-warm, so jit_precached is True by construction and
        # jit_warm_s has no dispatch-cache misses mixed in.
        traces_before = energymodel.jit_cache_stats()["traces"]
        t0 = time.perf_counter()
        e_j, t_j = energymodel.evaluate_networks(grid, nets, use_jax=use_jax)
        cold_s = time.perf_counter() - t0
        cold_hit = (use_jax and
                    energymodel.jit_cache_stats()["traces"] == traces_before)
        warm_s = _warm_min(
            lambda: energymodel.evaluate_networks(grid, nets,
                                                  use_jax=use_jax))

        err_e = float(np.max(np.abs(e_j - e_np) / e_np))
        err_t = float(np.max(np.abs(t_j - t_np) / t_np))
        _, inv = energymodel._dedup_count_rows(
            energymodel._cfg_struct_from_grid(np, grid))
        level = dict(
            name=name, points=grid.n, networks=len(nets),
            unique_count_rows=int(inv.max()) + 1,
            chunked=False,
            numpy_per_config_s=round(numpy_s, 4),
            jit_cold_s=round(cold_s, 4), jit_cold_cache_hit=cold_hit,
            jit_precached=True, jit_warm_s=round(warm_s, 4),
            speedup_warm=round(numpy_s / warm_s, 2),
            max_rel_err_energy=err_e, max_rel_err_latency=err_t)
        level.update(_pallas_columns(grid, nets, e_j, t_j))
        results.append(level)
        _emit(f"dse_scale_{name}", numpy_s * 1e6,
              f"{grid.n} pts: numpy {numpy_s:.2f}s vs jit {warm_s:.2f}s "
              f"warm → {numpy_s / warm_s:.1f}x, {_pallas_txt(level)}, "
              f"err<={max(err_e, err_t):.1e}")

    results.append(_bench_mega_level(nets, use_jax, quick))
    return results


def _bench_mega_level(nets, use_jax: bool, quick: bool) -> dict:
    """Chunked + sharded streaming at mega scale (a reduced grid in quick
    mode, so CI still covers the whole path).  The full [n_cfg, n_net]
    result of the chunked pass is kept (tiny — the savings are in the
    per-chunk intermediates) to cross-check the stream reductions; the
    unchunked reference runs on a subsampled slice only."""
    if quick:
        grid, chunk, name = (accelerator.ConfigGrid.product(
            rf_psum_words=accelerator.RF_PSUM_SIZES,
            noc_words_per_cycle=accelerator.NOC_WIDTHS), 512,
            "mega_quick_1350")
    else:
        grid, chunk, name = accelerator.mega_grid(), MEGA_CHUNK, "mega_49000"
    n_dev = energymodel.host_device_count()

    t0 = time.perf_counter()
    e_c, t_c = energymodel.evaluate_networks(grid, nets, use_jax=use_jax,
                                             chunk_size=chunk)
    cold_s = time.perf_counter() - t0
    warm_s = _warm_min(lambda: energymodel.evaluate_networks(
        grid, nets, use_jax=use_jax, chunk_size=chunk), reps=2)
    sharded_s = _warm_min(lambda: energymodel.evaluate_networks(
        grid, nets, use_jax=use_jax, chunk_size=chunk, shard=True),
        reps=2)

    sr = energymodel.stream_networks(grid, nets, chunk_size=chunk,
                                     use_jax=use_jax, shard=True)
    stream_s = _timed(lambda: energymodel.stream_networks(
        grid, nets, chunk_size=chunk, use_jax=use_jax, shard=True))[1] / 1e6
    edp = e_c * t_c
    stream_ok = (np.allclose(sr.min_metric, edp.min(axis=0), rtol=1e-9)
                 and np.array_equal(sr.argmin, edp.argmin(axis=0)))

    # unchunked reference on a subsampled slice (the full unchunked mega
    # run is exactly what chunking exists to avoid)
    sub = np.arange(0, grid.n, 97)
    e_r, t_r = energymodel.evaluate_networks(grid.take(sub), nets,
                                             use_jax=use_jax)
    err_e = float(np.max(np.abs(e_c[sub] - e_r) / e_r))
    err_t = float(np.max(np.abs(t_c[sub] - t_r) / t_r))

    level = dict(
        name=name, points=grid.n, networks=len(nets),
        chunked=True, chunk_size=chunk, n_devices=n_dev,
        jit_cold_s=round(cold_s, 4), jit_precached=True,
        jit_warm_s=round(warm_s, 4),
        sharded_warm_s=round(sharded_s, 4),
        shard_speedup=round(warm_s / sharded_s, 3),
        stream_s=round(stream_s, 4), stream_consistent=bool(stream_ok),
        max_rel_err_energy=err_e, max_rel_err_latency=err_t,
        subsample_stride=97,
        rss_now_mb=round(_rss_now_mb(), 1),
        rss_peak_process_mb=round(_rss_peak_mb(), 1))
    level.update(_pallas_columns(grid, nets, e_c, t_c, chunk=chunk))
    _emit(f"dse_scale_{name}", warm_s * 1e6,
          f"{grid.n} pts chunked({chunk}): {warm_s:.2f}s, sharded "
          f"{sharded_s:.2f}s ({n_dev} dev), stream {stream_s:.2f}s, "
          f"{_pallas_txt(level)}, "
          f"err<={max(err_e, err_t):.1e}, "
          f"rss {level['rss_peak_process_mb']:.0f}MB peak")
    return level


def _median_s(fn, reps: int = 3) -> float:
    """Median wall time over ``reps`` runs after ONE untimed pre-warm —
    the amortised treatment every baseline loop gets (PR 2 timed the bb
    loop once, cold, which made `speedup_vs_bb` swing run to run)."""
    fn()
    return float(np.median([_timed(fn)[1] / 1e6 for _ in range(reps)]))


def _warm_stat(fn, quick: bool, reps: int = 3) -> float:
    """Floors-relevant warm timing: full runs keep the min-of-reps
    steady-state number; ``--quick`` runs (small problems on noisy
    shared CI runners) take the median-of-3 instead, which one
    descheduled rep cannot drag around."""
    return _median_s(fn, reps=reps) if quick else _warm_min(fn, reps=reps)


def bench_partition_batch(nets) -> dict:
    """All (network × k∈2..8) pipeline splits: the looped bb/dp hot path
    that bench_table7_8 used per pair, vs ONE batch_partition call.

    Both baselines are pre-warmed and median-of-reps (see `_median_s`);
    the honest perf claim is `speedup_vs_bb_dp_loop` — the batch solver
    REPLACED the bb+dp pair loop, so that is the guardrailed ratio.
    `speedup_vs_bb` (batch vs the inexact bb heuristic alone) stays as an
    informational column; the PR 2 50×-vs-bb target was re-scoped after
    amortised re-measurement still put it at single digits on this host
    (docs/bench_schema.md#known-caveats)."""
    ks = tuple(range(2, 9))
    cfg = accelerator.AcceleratorConfig()
    lats = [energymodel.simulate_network(
        cfg, topology.get_network(n), n).layer_latencies for n in nets]

    def loop_bb():
        for lat in lats:
            for k in ks:
                partition.bb_partition(lat, k)

    def loop_dp():
        return [{k: partition.dp_partition(lat, k) for k in ks}
                for lat in lats]

    loop_bb_s = _median_s(loop_bb)
    loop_dp_s = _median_s(loop_dp)
    dp = loop_dp()

    batch_s = _warm_min(lambda: partition.batch_partition(lats, ks))
    res = partition.batch_partition(lats, ks)
    diffs = [abs(res[i][k].pipeline_latency - dp[i][k].pipeline_latency)
             / dp[i][k].pipeline_latency
             for i in range(len(lats)) for k in ks]
    out = dict(
        pairs=len(lats) * len(ks), networks=len(lats), k_range=[2, 8],
        loop_bb_s=round(loop_bb_s, 4), loop_dp_s=round(loop_dp_s, 4),
        baseline_reps=3, baseline_prewarmed=True,
        partition_batch_s=round(batch_s, 5),
        speedup_vs_bb=round(loop_bb_s / batch_s, 1),
        speedup_vs_bb_dp_loop=round((loop_bb_s + loop_dp_s) / batch_s, 1),
        max_rel_diff_vs_dp=float(max(diffs)),
        exact_vs_dp=bool(max(diffs) == 0.0))
    _emit("partition_batch", batch_s * 1e6,
          f"{out['pairs']} pairs: batch {batch_s * 1e3:.1f}ms vs loops "
          f"bb {loop_bb_s * 1e3:.0f}ms + dp {loop_dp_s * 1e3:.0f}ms → "
          f"{out['speedup_vs_bb_dp_loop']:.0f}x (bb only "
          f"{out['speedup_vs_bb']:.0f}x), exact={out['exact_vs_dp']}")
    return out


# ---------------------------------------------------------------------------
# Co-design level (schema v4): the batched heterogeneous layer→core
# schedule search vs the per-(chip, network) python loop it replaces,
# plus per-layer-path parity across every engine backend.
# ---------------------------------------------------------------------------


def _per_layer_parity(grid, nets) -> dict:
    """`per_layer=True` parity across jax / pallas / chunked / sharded
    against the numpy per-layer reference (all ≤1e-6 guardrailed)."""
    def err(a, b):
        d = np.abs(a - b)
        with np.errstate(invalid="ignore", divide="ignore"):
            r = np.where(b != 0, d / np.abs(b), d)
        return float(r.max())

    e_n, t_n = energymodel.evaluate_networks(grid, nets, backend="numpy",
                                             per_layer=True)
    e_j, t_j = energymodel.evaluate_networks(grid, nets, backend="jax",
                                             per_layer=True)
    e_c, t_c = energymodel.evaluate_networks(grid, nets, backend="jax",
                                             per_layer=True, chunk_size=64)
    e_s, t_s = energymodel.evaluate_networks(grid, nets, backend="jax",
                                             per_layer=True, shard=True)
    out = dict(
        max_rel_err_per_layer_jax=max(err(e_j, e_n), err(t_j, t_n)),
        max_rel_err_per_layer_chunked=max(err(e_c, e_j), err(t_c, t_j)),
        max_rel_err_per_layer_sharded=max(err(e_s, e_j), err(t_s, t_j)))
    if energymodel.pallas_available():
        e_p, t_p = energymodel.evaluate_networks(grid, nets,
                                                 backend="pallas",
                                                 per_layer=True)
        out["max_rel_err_per_layer_pallas"] = max(err(e_p, e_j),
                                                  err(t_p, t_j))
    else:                                              # pragma: no cover
        out["max_rel_err_per_layer_pallas"] = None
    return out


def bench_codesign(nets, quick: bool) -> dict:
    """Schema-v4 `codesign` level: every (chip candidate × network)
    heterogeneous layer→core schedule in ONE batch_schedule_hetero call,
    timed against the per-(chip, network) `schedule_hetero_oracle` loop
    it replaces (pre-warmed, median-of-reps), with exactness and
    per-layer-path parity guardrails."""
    networks = {n: topology.get_network(n) for n in nets}
    grid = accelerator.ConfigGrid.product()
    # quick keeps the full chip-enumeration shape (the batch solver's win
    # is amortising fixed dispatch over many problems — too few problems
    # and the bench measures overhead, not the solver)
    pool_size, m_cores, max_types = (5, 4, 3) if quick else (6, 4, 3)

    probs = hetero.codesign_problems(grid, networks, m_cores,
                                     max_types=max_types,
                                     pool_size=pool_size)

    lats = probs.lats                      # per-problem views, built once

    def loop_oracle():
        return [partition.schedule_hetero_oracle(lats[i], probs.counts[i])
                for i in range(probs.n_problems)]

    loop_s = _median_s(loop_oracle, reps=2 if quick else 3)
    oracle = loop_oracle()

    def batch():
        return partition.batch_schedule_hetero(
            probs.lat_dense, probs.counts, n_layers=probs.n_layers_b)

    batch_s = _warm_stat(batch, quick, reps=2 if quick else 3)
    res = batch()

    diffs = [abs(res.bottleneck[i] - oracle[i]["bottleneck"])
             / max(oracle[i]["bottleneck"], 1e-300)
             for i in range(probs.n_problems)]

    t0 = time.perf_counter()
    cd = hetero.co_design(grid, networks, m_cores, max_types=max_types,
                          pool_size=pool_size)
    codesign_s = time.perf_counter() - t0

    out = dict(
        name="codesign", points=grid.n, networks=len(networks),
        pool_size=pool_size, m_cores=m_cores, max_types=max_types,
        n_chips=len(probs.chips), problems=probs.n_problems,
        loop_oracle_s=round(loop_s, 4),
        schedule_batch_s=round(batch_s, 5),
        speedup_warm=round(loop_s / batch_s, 2),
        max_rel_diff_vs_oracle=float(max(diffs)),
        exact_vs_oracle=bool(max(diffs) == 0.0),
        codesign_end_to_end_s=round(codesign_s, 4),
        chip=dict(core_types=[grid.config_at(c).label()
                              for c in cd.core_types],
                  core_counts=cd.core_counts,
                  score=round(cd.score, 6),
                  homogeneous_score=round(cd.homogeneous_score, 6)))
    out.update(_per_layer_parity(grid, networks))
    _emit("codesign", batch_s * 1e6,
          f"{probs.n_problems} (chip,net) schedules: batch "
          f"{batch_s * 1e3:.1f}ms vs oracle loop {loop_s:.2f}s → "
          f"{out['speedup_warm']:.0f}x, exact={out['exact_vs_oracle']}, "
          f"chip {'+'.join(str(c) for c in cd.core_counts)} cores, "
          f"hetero/homog score {cd.score:.3f}/{cd.homogeneous_score:.3f}")
    return out


#: Warm-speedup floor of the batched co-design solver vs the oracle loop
#: (ISSUE 4 acceptance: ≥ 20× on full runs; quick runs solve a much
#: smaller problem set where fixed dispatch overhead dominates, so the
#: floor is relaxed there — benchmarks/floors.json keeps CI's copy).
CODESIGN_SPEEDUP_FLOOR = 20.0
CODESIGN_SPEEDUP_FLOOR_QUICK = 3.0

#: Speedup floor of the batched latency-bound Pareto sweep vs the
#: per-deadline python-loop rescoring it replaces (ISSUE 5 acceptance:
#: ≥ 10× on full runs; quick shares the chip-enumeration shape, so the
#: floor only relaxes for runner noise — benchmarks/floors.json again
#: keeps CI's copy).
PARETO_SPEEDUP_FLOOR = 10.0
PARETO_SPEEDUP_FLOOR_QUICK = 3.0


# ---------------------------------------------------------------------------
# codesign_mega level (schema v5): streamed candidate pool from the mega
# grid (one chunked stream_layer_topk pass — boundary sets + top-k +
# running minima, no [n_cfg, n_net(, n_layer)] matrices) + the batched
# latency-bound Pareto sweep vs the per-deadline python loop it replaces.
# ---------------------------------------------------------------------------


def _pareto_loop_baseline(norm_e, lat, norm_l, dl_abs):
    """The per-deadline python-loop rescoring `pareto_codesign` replaces:
    per (deadline × chip) feasibility + score in python, per-network
    per-deadline argmins, and O(n_chips²) dominance filters per network
    and on the network-mean plane.  Produces exactly the batched sweep's
    outputs, so exactness is asserted alongside the timing."""
    n_chips, n_net = norm_e.shape
    n_d = dl_abs.shape[1]
    best = np.full(n_d, -1, dtype=np.int64)
    best_net = np.full((n_net, n_d), -1, dtype=np.int64)
    for d in range(n_d):
        best_s = np.inf
        net_s = np.full(n_net, np.inf)
        for c in range(n_chips):
            feas = lat[c] <= dl_abs[:, d]
            if feas.all():
                s = norm_e[c].mean()
                if s < best_s:
                    best_s, best[d] = s, c
            for j in np.flatnonzero(feas):
                if norm_e[c, j] < net_s[j]:
                    net_s[j], best_net[j, d] = norm_e[c, j], c
    net_front = np.ones((n_chips, n_net), dtype=bool)
    for j in range(n_net):
        for c in range(n_chips):
            for o in range(n_chips):
                if (norm_e[o, j] <= norm_e[c, j] and lat[o, j] <= lat[c, j]
                        and (norm_e[o, j] < norm_e[c, j]
                             or lat[o, j] < lat[c, j])):
                    net_front[c, j] = False
                    break
    me, ml = norm_e.mean(axis=1), norm_l.mean(axis=1)
    chip_front = np.ones(n_chips, dtype=bool)
    for c in range(n_chips):
        for o in range(n_chips):
            if (me[o] <= me[c] and ml[o] <= ml[c]
                    and (me[o] < me[c] or ml[o] < ml[c])):
                chip_front[c] = False
                break
    return best, best_net, net_front, chip_front


def _pareto_matches(pc, loop_out, dl_abs) -> bool:
    """Exactness gate for the batched sweep vs the loop baseline.

    Feasibility masks, per-network argmins, and the per-network fronts
    involve only comparisons/selections on identical float inputs, so
    they must match BIT-EXACTLY.  The per-deadline best chip and the
    mean-plane chip front go through a mean reduction, which XLA and
    numpy may sum in different orders — a last-ulp difference between
    two near-tied chips can flip an argmin/dominance there, so those two
    accept index disagreements only between value-tied (≤1e-9 rel)
    picks."""
    l_best, l_best_net, l_front, l_chip_front = loop_out
    if not (np.array_equal(l_best_net, pc.best_chip_net)
            and np.array_equal(l_front, pc.net_frontier)):
        return False
    feas = pc.latency[:, :, None] <= dl_abs[None, :, :]
    loop_scores = np.where(feas, pc.norm_energy[:, :, None],
                           np.inf).mean(axis=1)
    for d in range(dl_abs.shape[1]):
        a, b = int(pc.best_chip[d]), int(l_best[d])
        if a == b:
            continue
        if a < 0 or b < 0:
            return False
        if not np.isclose(loop_scores[a, d], loop_scores[b, d],
                          rtol=1e-9, atol=0.0):
            return False
    if not np.array_equal(l_chip_front, pc.chip_frontier):
        me = pc.norm_energy.mean(axis=1)
        ml = pc.norm_latency.mean(axis=1)
        for c in np.flatnonzero(l_chip_front != pc.chip_frontier):
            tied = ((np.abs(me - me[c]) <= 1e-9 * np.abs(me[c]))
                    & (np.abs(ml - ml[c]) <= 1e-9 * np.abs(ml[c])))
            if tied.sum() < 2:
                return False
    return True


def bench_codesign_mega(nets, quick: bool) -> dict:
    """Schema-v5 `codesign_mega` level: mega-grid streaming co-design
    (the candidate pool streamed chunk by chunk, never a dense sweep)
    plus the batched Pareto sweep over ≥ 8 deadlines in ONE compiled
    call, timed against the per-deadline python-loop baseline."""
    networks = {n: topology.get_network(n) for n in nets}
    if quick:
        grid, chunk, name = (accelerator.ConfigGrid.product(
            rf_psum_words=accelerator.RF_PSUM_SIZES,
            noc_words_per_cycle=accelerator.NOC_WIDTHS), 256,
            "codesign_mega_quick_1350")
    else:
        grid, chunk, name = accelerator.mega_grid(), 2048, \
            "codesign_mega_49000"
    pool_size, m_cores, max_types = 6, 4, 3

    t0 = time.perf_counter()
    probs = hetero.codesign_problems_streaming(
        grid, networks, m_cores, max_types=max_types, pool_size=pool_size,
        chunk_size=chunk)
    stream_pool_s = time.perf_counter() - t0
    rss_after_stream = _rss_now_mb()

    # streamed pool == dense pool (quick grids only: a dense mega sweep is
    # exactly what the streaming path exists to avoid)
    pool_matches_dense = None
    if quick:
        dense = hetero.codesign_problems(grid, networks, m_cores,
                                         max_types=max_types,
                                         pool_size=pool_size)
        pool_matches_dense = bool(dense.pool == probs.pool)

    res = partition.batch_schedule_hetero(probs.lat_dense, probs.counts,
                                          n_layers=probs.n_layers_b)
    t0 = time.perf_counter()
    pc = hetero.pareto_codesign(probs, res, n_deadlines=12)
    build_s = time.perf_counter() - t0
    deadlines = pc.deadlines

    # the sweep re-run (new deadline grid, solved points reused) — the
    # apples-to-apples twin of the loop baseline below, which consumes
    # the same precomputed (energy, latency) points
    points = (pc.energy, pc.latency)
    pareto_s = _warm_stat(
        lambda: hetero.pareto_codesign(probs, deadlines=deadlines,
                                       points=points),
        quick, reps=2 if quick else 3)

    dl_abs = probs.min_latency[:, None] * deadlines[None, :]
    loop_s = _median_s(
        lambda: _pareto_loop_baseline(pc.norm_energy, pc.latency,
                                      pc.norm_latency, dl_abs),
        reps=2 if quick else 3)
    l_base = _pareto_loop_baseline(pc.norm_energy, pc.latency,
                                   pc.norm_latency, dl_abs)
    pareto_exact = _pareto_matches(pc, l_base, dl_abs)

    out = dict(
        name=name, points=grid.n, networks=len(networks),
        chunk_size=chunk, pool_size=pool_size, m_cores=m_cores,
        max_types=max_types, pool=[int(p) for p in probs.pool],
        n_chips=pc.n_chips, problems=probs.n_problems,
        n_deadlines=int(deadlines.size),
        deadline_lo=round(float(deadlines[0]), 6),
        deadline_hi=round(float(deadlines[-1]), 6),
        stream_pool_s=round(stream_pool_s, 4),
        pool_matches_dense=pool_matches_dense,
        pareto_build_s=round(build_s, 4),
        pareto_sweep_s=round(pareto_s, 5),
        pareto_loop_s=round(loop_s, 4),
        pareto_speedup=round(loop_s / pareto_s, 2),
        pareto_exact=pareto_exact,
        best_chip_by_deadline=[int(c) for c in pc.best_chip],
        frontier_sizes=[int(s) for s in pc.net_frontier.sum(axis=0)],
        rss_after_stream_mb=round(rss_after_stream, 1),
        rss_now_mb=round(_rss_now_mb(), 1),
        rss_peak_process_mb=round(_rss_peak_mb(), 1))
    _emit("codesign_mega", pareto_s * 1e6,
          f"{grid.n} pts streamed pool in {stream_pool_s:.1f}s "
          f"(rss {rss_after_stream:.0f}MB), pareto x{deadlines.size} "
          f"deadlines: {pareto_s * 1e3:.1f}ms vs loop {loop_s * 1e3:.0f}ms"
          f" → {out['pareto_speedup']:.0f}x, exact={pareto_exact}")
    return out


# ---------------------------------------------------------------------------
# slack level (schema v6): the energy-aware deadline-slack pass — every
# (chip candidate × network × deadline) cell re-scheduled toward cheaper
# core types in ONE batch_slack_schedule call, vs the per-cell
# slack_schedule_oracle loop it replaces.
# ---------------------------------------------------------------------------

#: Relative deadline grid (× the per-network single-config minimum
#: latency) — the tightest column leaves real-but-thin slack, the widest
#: is effectively energy-argmin.
SLACK_DEADLINES = (1.05, 1.25, 2.0, 4.0)

#: Warm-speedup floor of the batched slack solver vs the per-cell oracle
#: loop (ISSUE 8 acceptance: ≥ 10× on full runs; quick runs solve a far
#: smaller enumeration where fixed dispatch overhead dominates the
#: batch kernel — benchmarks/floors.json keeps CI's copy).
SLACK_SPEEDUP_FLOOR = 10.0
SLACK_SPEEDUP_FLOOR_QUICK = 2.0


def bench_slack(nets, quick: bool) -> dict:
    """Schema-v6 `slack` level: every (chip, network, deadline) energy-
    aware slack schedule in ONE batch_slack_schedule call, timed against
    the per-cell `slack_schedule_oracle` loop, with bit-exactness, weak
    energy-dominance and deadline-feasibility guardrails.

    The full run enumerates a LARGER chip pool than the `codesign` level
    (pool_size 8 vs 6): the depth-bucketed numpy kernel works on
    [rows, deadlines, types] slices whose per-op cost is dispatch-bound
    on small batches, so the solver's advantage is only honest at the
    enumeration scale the DSE service actually sweeps."""
    networks = {n: topology.get_network(n) for n in nets}
    grid = accelerator.ConfigGrid.product()
    pool_size, m_cores, max_types = (5, 4, 3) if quick else (8, 4, 3)
    probs = hetero.codesign_problems(grid, networks, m_cores,
                                     max_types=max_types,
                                     pool_size=pool_size)
    n_net = len(networks)
    n_chips = probs.n_problems // n_net
    t_max = probs.counts.shape[1]
    en = hetero._expand_pool_tensor(probs.e_layer, probs.chips, n_net,
                                    t_max)
    rel = np.asarray(SLACK_DEADLINES)
    dl = np.tile(probs.min_latency[:, None] * rel[None, :], (n_chips, 1))

    base = partition.batch_schedule_hetero(
        probs.lat_dense, probs.counts, n_layers=probs.n_layers_b)

    def batch():
        return partition.batch_slack_schedule(
            probs.lat_dense, en, probs.counts, dl,
            n_layers=probs.n_layers_b, use_jax=False, base=base)

    batch_s = _warm_stat(batch, quick)
    sl = batch()

    def loop_oracle():
        out = []
        for i in range(probs.n_problems):
            nl_i = int(probs.n_layers_b[i])
            lat_i = probs.lat_dense[i, :, :nl_i]
            e_i = en[i, :, :nl_i]
            cnt_i = probs.counts[i]
            for d in range(rel.size):
                out.append(partition.slack_schedule_oracle(
                    lat_i, e_i, cnt_i, dl[i, d]))
        return out

    # the oracle loop is timed ONCE — a median-of-reps treatment would
    # quadruple a baseline already tens of seconds long for a ratio this
    # lopsided; the timed run's outputs double as the parity reference
    oracle, loop_us = _timed(loop_oracle)
    loop_s = loop_us / 1e6

    shape = (probs.n_problems, rel.size)
    o_bott = np.array([o["bottleneck"] for o in oracle]).reshape(shape)
    o_energy = np.array([o["energy"] for o in oracle]).reshape(shape)
    o_moves = np.array([o["n_moves"] for o in oracle]).reshape(shape)
    o_feas = np.array([o["feasible"] for o in oracle]).reshape(shape)
    exact = (np.array_equal(sl.bottleneck, o_bott)
             and np.array_equal(sl.energy, o_energy)
             and np.array_equal(sl.n_moves, o_moves)
             and np.array_equal(sl.feasible, o_feas))

    def rel_diff(a, b):
        fin = np.isfinite(b)
        if not fin.any():
            return 0.0
        d = np.abs(a[fin] - b[fin])
        return float((d / np.maximum(np.abs(b[fin]), 1e-300)).max(
            initial=0.0))

    max_rel = max(rel_diff(sl.bottleneck, o_bott),
                  rel_diff(sl.energy, o_energy))

    # energy of the UNmoved base assignment per problem: a deadline equal
    # to the base bottleneck leaves zero slack, so the solver returns the
    # base schedule (and its sequentially-summed energy) verbatim
    base_e = partition.batch_slack_schedule(
        probs.lat_dense, en, probs.counts, base.bottleneck[:, None],
        n_layers=probs.n_layers_b, use_jax=False, base=base).energy[:, 0]
    with np.errstate(invalid="ignore"):
        saved_pct = 100.0 * (base_e[:, None] - sl.energy) / base_e[:, None]
    dominance_ok = bool(
        (sl.energy <= base_e[:, None] * (1.0 + 1e-9)).all())
    # a weak chip candidate's latency-argmin bottleneck can genuinely
    # exceed the tightest budget (deadlines are relative to the grid-wide
    # single-config minimum), so infeasible cells are allowed — the
    # guardrail is CONSISTENCY: the flag matches bottleneck <= deadline
    # exactly, and every feasible cell's schedule fits its budget
    deadline_met_ok = bool(
        (sl.feasible == (sl.bottleneck <= dl)).all()
        and (sl.bottleneck[sl.feasible] <= dl[sl.feasible]).all())

    out = dict(
        name="slack", points=grid.n, networks=len(networks),
        pool_size=pool_size, m_cores=m_cores, max_types=max_types,
        n_chips=n_chips, problems=probs.n_problems,
        n_deadlines=int(rel.size),
        deadlines_rel=[float(r) for r in rel],
        slack_batch_s=round(batch_s, 4),
        oracle_loop_s=round(loop_s, 3), baseline_reps=1,
        speedup_warm=round(loop_s / batch_s, 2),
        max_rel_diff_vs_oracle=max_rel,
        exact_vs_oracle=bool(exact),
        moves_total=int(sl.n_moves.sum()),
        moved_cells_pct=round(
            100.0 * float((sl.n_moves > 0).mean()), 2),
        feasible_cells_pct=round(
            100.0 * float(sl.feasible.mean()), 2),
        energy_saved_mean_pct=round(float(saved_pct.mean()), 3),
        energy_saved_max_pct=round(float(saved_pct.max()), 3),
        dominance_ok=dominance_ok,
        deadline_met_ok=deadline_met_ok)
    _emit("slack", batch_s * 1e6,
          f"{probs.n_problems}x{rel.size} (chip,net,deadline) cells: "
          f"batch {batch_s * 1e3:.0f}ms vs oracle loop {loop_s:.1f}s → "
          f"{out['speedup_warm']:.0f}x, exact={out['exact_vs_oracle']}, "
          f"{out['moves_total']} moves save "
          f"{out['energy_saved_mean_pct']:.1f}% energy on average")
    return out


def _check_bench_payload(payload: dict, quick: bool = False) -> list:
    """Schema/parity guardrails — CI fails on regressions here (documented
    in docs/bench_schema.md; keep the two in sync)."""
    problems = []
    for key in ("schema", "cpu_count", "n_devices", "backends", "levels",
                "partition", "codesign", "codesign_mega", "slack",
                "persistent_cache"):
        if key not in payload:
            problems.append(f"missing payload key {key!r}")
    if payload.get("schema") != "bench_dse/v6":
        problems.append(f"unexpected schema {payload.get('schema')!r}")
    for lv in payload.get("levels", []):
        for key in ("max_rel_err_energy", "max_rel_err_latency",
                    "max_rel_err_pallas_energy",
                    "max_rel_err_pallas_latency"):
            if key not in lv:
                problems.append(f"level {lv.get('name')}: missing {key!r}")
            elif lv[key] is not None and lv[key] > 1e-6:
                problems.append(
                    f"level {lv.get('name')}: {key}={lv.get(key):.2e}")
        if (payload.get("backends", {}).get("pallas")
                and lv.get("pallas_warm_s") is None):
            problems.append(
                f"level {lv.get('name')}: pallas available but no "
                "pallas_warm_s timing recorded")
        if lv.get("chunked") and not lv.get("stream_consistent", True):
            problems.append(
                f"level {lv.get('name')}: stream reductions diverged")
    part = payload.get("partition", {})
    if part.get("max_rel_diff_vs_dp", 1.0) > 1e-12:
        problems.append(
            f"batch_partition vs dp: {part.get('max_rel_diff_vs_dp'):.2e}")
    cod = payload.get("codesign", {})
    if cod:
        if cod.get("max_rel_diff_vs_oracle", 1.0) > 1e-6:
            problems.append(
                "codesign: max_rel_diff_vs_oracle "
                f"{cod.get('max_rel_diff_vs_oracle'):.2e}")
        floor = (CODESIGN_SPEEDUP_FLOOR_QUICK if quick
                 else CODESIGN_SPEEDUP_FLOOR)
        if cod.get("speedup_warm", 0.0) < floor:
            problems.append(
                f"codesign: speedup_warm {cod.get('speedup_warm')} < "
                f"{floor}x floor")
        for key in ("max_rel_err_per_layer_jax",
                    "max_rel_err_per_layer_chunked",
                    "max_rel_err_per_layer_sharded",
                    "max_rel_err_per_layer_pallas"):
            if key not in cod:
                problems.append(f"codesign: missing {key!r}")
            elif cod[key] is not None and cod[key] > 1e-6:
                problems.append(f"codesign: {key}={cod.get(key):.2e}")
    mega = payload.get("codesign_mega", {})
    if mega:
        floor = (PARETO_SPEEDUP_FLOOR_QUICK if quick
                 else PARETO_SPEEDUP_FLOOR)
        if mega.get("pareto_speedup", 0.0) < floor:
            problems.append(
                f"codesign_mega: pareto_speedup "
                f"{mega.get('pareto_speedup')} < {floor}x floor")
        if not mega.get("pareto_exact", False):
            problems.append(
                "codesign_mega: batched pareto sweep diverged from the "
                "per-deadline loop baseline")
        if mega.get("pool_matches_dense") is False:
            problems.append(
                "codesign_mega: streamed pool != dense pool")
    sla = payload.get("slack", {})
    if sla:
        if sla.get("max_rel_diff_vs_oracle", 1.0) > 1e-6:
            problems.append(
                "slack: max_rel_diff_vs_oracle "
                f"{sla.get('max_rel_diff_vs_oracle'):.2e}")
        floor = (SLACK_SPEEDUP_FLOOR_QUICK if quick
                 else SLACK_SPEEDUP_FLOOR)
        if sla.get("speedup_warm", 0.0) < floor:
            problems.append(
                f"slack: speedup_warm {sla.get('speedup_warm')} < "
                f"{floor}x floor")
        if not sla.get("dominance_ok", False):
            problems.append(
                "slack: an energy-aware schedule costs MORE energy than "
                "its latency-argmin base (weak dominance broken)")
        if not sla.get("deadline_met_ok", False):
            problems.append(
                "slack: a cell misses its deadline (infeasible or "
                "bottleneck above the budget)")
    return problems


def _bench_warnings(payload: dict) -> list:
    """Non-fatal perf-target checks (ISSUE 2 acceptance asked for sharded
    ≥1.3x; on hosts where XLA's single-device inter-op parallelism
    already saturates the cores this is not reachable — surface the
    shortfall without failing CI).  The PR 2 ``speedup_vs_bb ≥ 50×``
    target was RE-SCOPED in ISSUE 4: the amortised (pre-warmed,
    median-of-reps) re-measurement still lands single-digit vs the
    inexact bb heuristic alone, so the guardrailed ratio is now the
    honest one — batch vs the bb+dp pair loop it actually replaced."""
    warns = []
    for lv in payload.get("levels", []):
        if lv.get("chunked") and lv.get("shard_speedup", 9.9) < 1.3:
            warns.append(
                f"level {lv.get('name')}: shard_speedup "
                f"{lv.get('shard_speedup')} < 1.3 target "
                f"({lv.get('n_devices')} devices)")
        peak = lv.get("rss_peak_process_mb", 0.0)
        if peak > 8192:
            warns.append(
                f"level {lv.get('name')}: process peak RSS {peak:.0f}MB "
                "> 8GB budget")
    mega = payload.get("codesign_mega", {})
    if mega.get("rss_after_stream_mb", 0.0) > 1536:
        warns.append(
            f"codesign_mega: rss_after_stream_mb "
            f"{mega.get('rss_after_stream_mb'):.0f}MB > ~1.5GB budget "
            "for the streamed mega pool")
    part = payload.get("partition", {})
    # only meaningful at full problem size — quick's 42-pair problem is
    # dominated by fixed dispatch and would always "warn"
    if (part.get("pairs", 0) >= 100
            and part.get("speedup_vs_bb_dp_loop", 99.0) < 50.0):
        warns.append(
            f"partition: speedup_vs_bb_dp_loop "
            f"{part.get('speedup_vs_bb_dp_loop')} < 50x target (vs bb "
            f"alone: {part.get('speedup_vs_bb')}x, informational)")
    return warns


def write_bench_json(levels: list, part: dict, codesign: dict,
                     codesign_mega: dict, slack: dict, cache_info: dict,
                     quick: bool) -> None:
    use_jax = dse._use_jax_default()
    payload = dict(
        schema="bench_dse/v6",
        cpu_count=os.cpu_count(),
        n_devices=energymodel.host_device_count(),
        backends=dict(jax=use_jax,
                      pallas=energymodel.pallas_available()),
        persistent_cache=cache_info,
        jit_cache=energymodel.jit_cache_stats(),
        levels=levels,
        partition=part,
        codesign=codesign,
        codesign_mega=codesign_mega,
        slack=slack)
    if use_jax:
        import jax
        payload["jax"] = jax.__version__
    else:                                              # pragma: no cover
        payload["jax"] = None                          # numpy-only fallback
    # quick runs use reduced grids — record them beside, never clobber,
    # the full-run trajectory file
    path = BENCH_DSE_QUICK_JSON if quick else BENCH_DSE_JSON
    path.write_text(json.dumps(payload, indent=2) + "\n")
    _emit("bench_dse_json", 0.0, f"wrote {path}")

    for w in _bench_warnings(payload):
        print(f"BENCH WARN: {w}", file=sys.stderr)
    problems = _check_bench_payload(payload, quick=quick)
    if problems:
        for p in problems:
            print(f"BENCH CHECK FAILED: {p}", file=sys.stderr)
        raise SystemExit(1)
    _emit("bench_dse_check", 0.0, "schema/parity guardrails passed")


def bench_table1_2(sweeps):
    """Tables 1–2: μ^p_min / δ^max_min per array, ifmap- and psum-swept."""
    def run():
        rows = []
        for net, sw in sweeps.items():
            t1 = dse.mu_delta(sw, swept="ifmap")
            t2 = dse.mu_delta(sw, swept="psum")
            for arr in sw.arrays:
                rows.append([net, f"{arr[0]}x{arr[1]}",
                             f"{t1[arr][0]:.2f}", f"{t1[arr][1]:.2f}",
                             f"{t2[arr][0]:.2f}", f"{t2[arr][1]:.2f}"])
        return rows

    rows, us = _timed(run)
    _write("table1_2_mu_delta", ["network", "array", "mu_ifmap",
                                 "delta_ifmap", "mu_psum", "delta_psum"],
           rows)
    d16 = [float(r[5]) for r in rows if r[1] == "16x16"]
    _emit("table1_2_mu_delta", us,
          f"psum delta@[16x16] mean={np.mean(d16):.1f}% (paper 4.6-112%)")


def bench_table3(sweeps):
    """Table 3: Δ^max_min over the 25-point space per array."""
    def run():
        rows = []
        for net, sw in sweeps.items():
            d = dse.delta_whole_space(sw)
            rows.append([net] + [f"{d[a]:.2f}" for a in sw.arrays])
        return rows

    rows, us = _timed(run)
    arrays = next(iter(sweeps.values())).arrays
    _write("table3_delta", ["network"] + [f"{a[0]}x{a[1]}" for a in arrays],
           rows)
    vals = [float(v) for r in rows for v in r[1:]]
    _emit("table3_delta", us,
          f"range {min(vals):.0f}-{max(vals):.0f}% (paper 12-114%)")


def bench_table4(sweeps):
    """Table 4: EDP mean/max spread over the whole space."""
    def run():
        return [[net, f"{m:.1f}", f"{mx:.1f}"]
                for net, (m, mx) in
                ((n, dse.edp_spread(sw)) for n, sw in sweeps.items())]

    rows, us = _timed(run)
    _write("table4_edp_spread", ["network", "mean_pct", "max_pct"], rows)
    means = [float(r[1]) for r in rows]
    _emit("table4_edp_spread", us,
          f"mean spread {min(means):.0f}-{max(means):.0f}% (paper 17-130%)")


def bench_table5(sweeps):
    """Table 5: per-network 5%-boundary configurations + chip design."""
    def run():
        rows = []
        for net, sw in sweeps.items():
            cells = dse.boundary_configs(sw, bound=0.05)
            rows.append([net, len(cells),
                         " | ".join(sw.cell_label(c) for c in cells[:6])])
        chip = hetero.design_chip(sweeps, bound=0.05, max_cores=3)
        return rows, chip

    (rows, chip), us = _timed(run)
    _write("table5_boundary_configs", ["network", "n_configs",
                                       "configs(first 6)"], rows)
    _emit("table5_boundary_configs", us,
          f"core types={len(chip.core_types)}: "
          + "; ".join(chip.core_label(i)
                      for i in range(len(chip.core_types))))
    return chip


def bench_table6(sweeps, chip):
    """Table 6: Δ_E/Δ_D/Δ_EDP on non-corresponding cores + savings."""
    def run():
        rows = []
        for net in sorted(chip.assignment):
            own = chip.assignment[net]
            worst = dict(dE=0.0, dD=0.0, dEDP=0.0)
            for other in range(len(chip.core_types)):
                if other == own:
                    continue
                pen = hetero.cross_penalty(chip, net, other)
                if pen["dEDP"] > worst["dEDP"]:
                    worst = pen
            rows.append([net, f"{worst['dE']:.2f}", f"{worst['dD']:.2f}",
                         f"{worst['dEDP']:.2f}"])
        sav = hetero.savings_summary(chip)
        return rows, sav

    (rows, sav), us = _timed(run)
    _write("table6_cross_penalty", ["network", "dE_pct", "dD_pct",
                                    "dEDP_pct"], rows)
    es = max(v["energy_saved"] for v in sav.values())
    ed = max(v["edp_saved"] for v in sav.values())
    _emit("table6_cross_penalty", us,
          f"max saved: energy {es:.0f}% / EDP {ed:.0f}% (paper 36%/67%)")


def bench_table7_8(nets):
    """Tables 7–8: Alg. II distribution on the paper's two core configs.

    The optimal column comes from ONE ``batch_partition`` call over every
    (network, k) pair — the per-pair dp loop this replaces dominated the
    seed's table time; bb stays as the paper's per-network algorithm."""
    cfg3 = accelerator.AcceleratorConfig(array_rows=32, array_cols=32,
                                         gb_psum_kb=54, gb_ifmap_kb=54)
    cfg4 = accelerator.AcceleratorConfig(array_rows=12, array_cols=14,
                                         gb_psum_kb=216, gb_ifmap_kb=54)

    def run():
        lats, klist = [], []
        for net in nets:
            layers = topology.get_network(net)
            cat1 = net in topology.CATEGORY_1
            cfg, k = (cfg3, 3) if cat1 else (cfg4, 4)
            rep = energymodel.simulate_network(cfg, layers, net)
            lats.append(rep.layer_latencies)
            klist.append(k)
        batch = partition.batch_partition(lats, (3, 4))
        rows = []
        for i, net in enumerate(nets):
            k = klist[i]
            bb = partition.bb_partition(lats[i], k)
            opt = batch[i][k]
            rows.append([net, k,
                         " ".join(f"({a},{b})" for a, b in bb.table_row()),
                         f"{bb.speedup:.2f}", f"{opt.speedup:.2f}"])
        return rows

    rows, us = _timed(run)
    _write("table7_8_distribution", ["network", "cores", "(l_init,n_C)",
                                     "speedup_bb", "speedup_optimal"], rows)
    s = [float(r[3]) for r in rows]
    _emit("table7_8_distribution", us,
          f"speedups {min(s):.2f}-{max(s):.2f} (paper 2.01-3.92)")


def bench_autoshard():
    """TPU adaptation: sharding-policy DSE + fleet design (Table-5 analogue)."""
    from repro.configs import ARCHS

    def run():
        rows = []
        for name, cfg in ARCHS.items():
            scored = autoshard.sweep(cfg, n_chips=256, seq_len=4096,
                                     global_batch=256)
            best, s = scored[0]
            rows.append([name, best.name, f"{s * 1e3:.2f}"])
        fleet = autoshard.design_fleet(
            {n: c for n, c in ARCHS.items()}, n_chips=256, seq_len=4096,
            global_batch=256, max_policies=3)
        return rows, fleet

    (rows, fleet), us = _timed(run)
    _write("autoshard_policies", ["arch", "best_policy", "step_ms"], rows)
    _emit("autoshard_fleet", us,
          f"{len(fleet['policies'])} fleet policies cover all 10 archs: "
          + ", ".join(fleet["policies"]))


def bench_pipeline_stages():
    """B&B pipeline staging from the TPU cost model (Alg. II, TPU edition)."""
    from repro.configs import ARCHS
    from repro.core.tpu_costmodel import layer_costs

    def run():
        rows = []
        for name in ("qwen2.5-32b", "qwen2-vl-72b", "recurrentgemma-9b",
                     "arctic-480b"):
            cfg = ARCHS[name]
            costs = layer_costs(cfg, ShardingPolicy("p", dp=64, tp=4),
                                seq_len=4096, global_batch=256)
            lat = [c.time_s for c in costs]
            for k in (2, 4):
                p = partition.bb_partition(lat, k)
                rows.append([name, k, f"{p.speedup:.2f}",
                             f"{p.pipeline_latency * 1e3:.2f}"])
        return rows

    rows, us = _timed(run)
    _write("pipeline_stages", ["arch", "stages", "speedup",
                               "stage_ms"], rows)
    s = [float(r[2]) for r in rows if r[1] == 4]
    _emit("pipeline_stages", us,
          f"4-stage speedups {min(s):.2f}-{max(s):.2f}")


def bench_fig5_6_7(sweeps):
    """Fig. 5/6/7: energy & latency curves vs GB sizes per array (CSV)."""
    def run():
        rows = []
        for net in ("VGG16", "ResNet50"):
            sw = sweeps.get(net)
            if sw is None:
                return []
            for a, arr in enumerate(sw.arrays):
                for pi, ps in enumerate(sw.psum_kb):
                    for ii, ifm in enumerate(sw.ifmap_kb):
                        rows.append([net, f"{arr[0]}x{arr[1]}", ps, ifm,
                                     f"{sw.energy[a, pi, ii]:.6e}",
                                     f"{sw.latency[a, pi, ii]:.6e}"])
        return rows

    rows, us = _timed(run)
    if rows:
        _write("fig5_6_7_curves", ["network", "array", "gb_psum_kb",
                                   "gb_ifmap_kb", "energy_pj",
                                   "latency_ns"], rows)
        _emit("fig5_6_7_curves", us, f"{len(rows)} curve points")


def bench_roofline_table():
    """§Roofline: aggregate the dry-run JSON cells into the report table."""
    import json

    def run():
        rows = []
        for f in sorted(Path("experiments/dryrun").glob("*__single.json")):
            r = json.loads(f.read_text())
            if r.get("status") != "ok":
                continue
            rl = r["roofline"]
            rows.append([
                r["arch"], r["shape"], f"{r['per_device_gib']:.2f}",
                f"{rl['compute_s']:.4f}", f"{rl['memory_s']:.4f}",
                f"{rl['collective_s']:.4f}", rl["bottleneck"],
                f"{rl['useful_flops_ratio']:.3f}", f"{rl['mfu']:.4f}"])
        return rows

    rows, us = _timed(run)
    if rows:
        _write("roofline_single_pod", ["arch", "shape", "gib_per_dev",
                                       "compute_s", "memory_s",
                                       "collective_s", "bottleneck",
                                       "useful_flops", "mfu"], rows)
        bn = [r[6] for r in rows]
        _emit("roofline_single_pod", us,
              f"{len(rows)} cells; bottlenecks: "
              f"compute={bn.count('compute')} memory={bn.count('memory')} "
              f"collective={bn.count('collective')}")
    else:
        _emit("roofline_single_pod", us, "no dry-run cells found (run "
              "python -m repro.launch.dryrun first)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    nets = QUICK_NETS if args.quick else PAPER_NETS
    cache_info = _enable_persistent_cache()

    print("name,us_per_call,derived")
    if cache_info.get("dir"):
        _emit("persistent_cache", 0.0,
              f"enabled={cache_info['enabled']} dir={cache_info['dir']}")
    sweeps, us = _timed(lambda: _sweeps(nets))
    _emit("dse_sweep_all", us, f"{len(nets)} networks x 150 configs")
    levels = bench_dse_scale(quick=args.quick)
    part = bench_partition_batch(nets)
    codesign = bench_codesign(nets, quick=args.quick)
    codesign_mega = bench_codesign_mega(nets, quick=args.quick)
    slack = bench_slack(nets, quick=args.quick)
    bench_table1_2(sweeps)
    bench_table3(sweeps)
    bench_table4(sweeps)
    chip = bench_table5(sweeps)
    bench_table6(sweeps, chip)
    bench_table7_8(nets)
    bench_fig5_6_7(sweeps)
    bench_autoshard()
    bench_pipeline_stages()
    bench_roofline_table()
    write_bench_json(levels, part, codesign, codesign_mega, slack,
                     cache_info, quick=args.quick)


if __name__ == "__main__":
    main()
