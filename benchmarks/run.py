"""Benchmark harness: one function per paper table/figure + the TPU
roofline/autoshard analyses.  Prints ``name,us_per_call,derived`` CSV rows
and writes the full tables to experiments/tables/*.csv.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import csv
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import (accelerator, dse, energymodel, hetero, partition,
                        rs_mapping, topology)
from repro.core import autoshard
from repro.core.tpu_costmodel import ShardingPolicy, step_time

OUT = Path("experiments/tables")
BENCH_DSE_JSON = Path("BENCH_dse.json")

PAPER_NETS = list(topology.NETWORKS)
QUICK_NETS = ["AlexNet", "VGG16", "GoogleNet", "ResNet50", "MobileNetV2",
              "Xception"]


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _write(name, header, rows):
    OUT.mkdir(parents=True, exist_ok=True)
    with open(OUT / f"{name}.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)


def _sweeps(nets):
    # one batched jit call: every network × the whole grid
    return dse.sweep_networks({n: topology.get_network(n) for n in nets})


# ---------------------------------------------------------------------------
# DSE engine scaling: numpy-per-config (the seed implementation) vs the
# batched jit engine, at 150 / 1,350 / 5,400 grid points.  Results land in
# BENCH_dse.json (machine-readable) so future PRs can track the trajectory.
# ---------------------------------------------------------------------------

def _seed_numpy_sweep(layers, configs):
    """The seed's design-space loop, verbatim: one AcceleratorConfig object
    per grid point, per-config numpy struct rows, full [n_cfg, n_layer]
    energy math summed at the end.  Kept here as the reference baseline the
    batched engine is measured (and parity-checked) against."""
    compute = [l for l in layers if l.kind != "input"]
    lay = rs_mapping.layer_struct(np, compute)
    lay = {k: np.asarray(v, dtype=np.float64)[None, :]
           for k, v in lay.items()}
    cfg_rows = [energymodel._cfg_struct(np, c) for c in configs]
    cfgs = {k: np.stack([np.float64(c[k]) for c in cfg_rows])[:, None]
            for k in cfg_rows[0]}
    ct = energymodel._counts(np, cfgs, lay)
    el = energymodel._energy_latency(np, cfgs, lay, ct)
    return el["energy"].sum(-1), el["latency"].sum(-1)


def _dse_scale_levels(quick: bool):
    paper = dict(arrays=accelerator.ARRAY_SIZES,
                 gb_psum_kb=accelerator.GB_SIZES_KB,
                 gb_ifmap_kb=accelerator.GB_SIZES_KB)
    levels = [("paper_150", accelerator.ConfigGrid.product(**paper))]
    if not quick:        # quick: one smoke level, no extra cold compiles
        levels += [
            ("extended_1350", accelerator.ConfigGrid.product(
                **paper, rf_psum_words=accelerator.RF_PSUM_SIZES,
                noc_words_per_cycle=accelerator.NOC_WIDTHS)),
            ("extended_5400", accelerator.extended_grid()),
        ]
    return levels


def bench_dse_scale(quick: bool = False) -> None:
    nets = {n: topology.get_network(n) for n in topology.NETWORKS}
    use_jax = dse._use_jax_default()
    results = []
    for name, grid in _dse_scale_levels(quick):
        # seed path: per-network numpy loop over per-point config objects.
        # (Objects built once per level — the seed rebuilt them per network,
        # so this baseline is conservative.)
        configs = [grid.config_at(i) for i in range(grid.n)]
        t0 = time.perf_counter()
        e_np = np.empty((grid.n, len(nets)))
        t_np = np.empty((grid.n, len(nets)))
        for j, layers in enumerate(nets.values()):
            e_np[:, j], t_np[:, j] = _seed_numpy_sweep(layers, configs)
        numpy_s = time.perf_counter() - t0

        # batched jit engine: one compiled call, cold then warm.  "cold" is
        # the first call at this level; jit_precached records whether an
        # earlier same-shape call (e.g. main()'s table sweep) had already
        # compiled it, in which case cold_s is really a cache hit.
        traces_before = energymodel.jit_cache_stats()["traces"]
        t0 = time.perf_counter()
        e_j, t_j = energymodel.evaluate_networks(grid, nets, use_jax=use_jax)
        cold_s = time.perf_counter() - t0
        precached = (use_jax and
                     energymodel.jit_cache_stats()["traces"] == traces_before)
        warm_s = min(_timed(
            lambda: energymodel.evaluate_networks(grid, nets,
                                                  use_jax=use_jax))[1] / 1e6
            for _ in range(2))

        err_e = float(np.max(np.abs(e_j - e_np) / e_np))
        err_t = float(np.max(np.abs(t_j - t_np) / t_np))
        _, inv = energymodel._dedup_count_rows(
            energymodel._cfg_struct_from_grid(np, grid))
        level = dict(
            name=name, points=grid.n, networks=len(nets),
            unique_count_rows=int(inv.max()) + 1,
            numpy_per_config_s=round(numpy_s, 4),
            jit_cold_s=round(cold_s, 4), jit_precached=precached,
            jit_warm_s=round(warm_s, 4),
            speedup_warm=round(numpy_s / warm_s, 2),
            max_rel_err_energy=err_e, max_rel_err_latency=err_t)
        results.append(level)
        _emit(f"dse_scale_{name}", numpy_s * 1e6,
              f"{grid.n} pts: numpy {numpy_s:.2f}s vs jit {warm_s:.2f}s "
              f"warm → {numpy_s / warm_s:.1f}x, err<={max(err_e, err_t):.1e}")

    if quick:
        # quick runs omit the 5,400-point level — don't clobber the
        # full-run trajectory record
        _emit("bench_dse_json", 0.0,
              f"quick mode: {BENCH_DSE_JSON} left untouched")
        return
    payload = dict(
        schema="bench_dse/v1",
        cpu_count=os.cpu_count(),
        jit_cache=energymodel.jit_cache_stats(),
        levels=results)
    if use_jax:
        import jax
        payload["jax"] = jax.__version__
    else:                                              # pragma: no cover
        payload["jax"] = None                          # numpy-only fallback
    BENCH_DSE_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    _emit("bench_dse_json", 0.0, f"wrote {BENCH_DSE_JSON}")


def bench_table1_2(sweeps):
    """Tables 1–2: μ^p_min / δ^max_min per array, ifmap- and psum-swept."""
    def run():
        rows = []
        for net, sw in sweeps.items():
            t1 = dse.mu_delta(sw, swept="ifmap")
            t2 = dse.mu_delta(sw, swept="psum")
            for arr in sw.arrays:
                rows.append([net, f"{arr[0]}x{arr[1]}",
                             f"{t1[arr][0]:.2f}", f"{t1[arr][1]:.2f}",
                             f"{t2[arr][0]:.2f}", f"{t2[arr][1]:.2f}"])
        return rows

    rows, us = _timed(run)
    _write("table1_2_mu_delta", ["network", "array", "mu_ifmap",
                                 "delta_ifmap", "mu_psum", "delta_psum"],
           rows)
    d16 = [float(r[5]) for r in rows if r[1] == "16x16"]
    _emit("table1_2_mu_delta", us,
          f"psum delta@[16x16] mean={np.mean(d16):.1f}% (paper 4.6-112%)")


def bench_table3(sweeps):
    """Table 3: Δ^max_min over the 25-point space per array."""
    def run():
        rows = []
        for net, sw in sweeps.items():
            d = dse.delta_whole_space(sw)
            rows.append([net] + [f"{d[a]:.2f}" for a in sw.arrays])
        return rows

    rows, us = _timed(run)
    arrays = next(iter(sweeps.values())).arrays
    _write("table3_delta", ["network"] + [f"{a[0]}x{a[1]}" for a in arrays],
           rows)
    vals = [float(v) for r in rows for v in r[1:]]
    _emit("table3_delta", us,
          f"range {min(vals):.0f}-{max(vals):.0f}% (paper 12-114%)")


def bench_table4(sweeps):
    """Table 4: EDP mean/max spread over the whole space."""
    def run():
        return [[net, f"{m:.1f}", f"{mx:.1f}"]
                for net, (m, mx) in
                ((n, dse.edp_spread(sw)) for n, sw in sweeps.items())]

    rows, us = _timed(run)
    _write("table4_edp_spread", ["network", "mean_pct", "max_pct"], rows)
    means = [float(r[1]) for r in rows]
    _emit("table4_edp_spread", us,
          f"mean spread {min(means):.0f}-{max(means):.0f}% (paper 17-130%)")


def bench_table5(sweeps):
    """Table 5: per-network 5%-boundary configurations + chip design."""
    def run():
        rows = []
        for net, sw in sweeps.items():
            cells = dse.boundary_configs(sw, bound=0.05)
            rows.append([net, len(cells),
                         " | ".join(sw.cell_label(c) for c in cells[:6])])
        chip = hetero.design_chip(sweeps, bound=0.05, max_cores=3)
        return rows, chip

    (rows, chip), us = _timed(run)
    _write("table5_boundary_configs", ["network", "n_configs",
                                       "configs(first 6)"], rows)
    _emit("table5_boundary_configs", us,
          f"core types={len(chip.core_types)}: "
          + "; ".join(chip.core_label(i)
                      for i in range(len(chip.core_types))))
    return chip


def bench_table6(sweeps, chip):
    """Table 6: Δ_E/Δ_D/Δ_EDP on non-corresponding cores + savings."""
    def run():
        rows = []
        for net in sorted(chip.assignment):
            own = chip.assignment[net]
            worst = dict(dE=0.0, dD=0.0, dEDP=0.0)
            for other in range(len(chip.core_types)):
                if other == own:
                    continue
                pen = hetero.cross_penalty(chip, net, other)
                if pen["dEDP"] > worst["dEDP"]:
                    worst = pen
            rows.append([net, f"{worst['dE']:.2f}", f"{worst['dD']:.2f}",
                         f"{worst['dEDP']:.2f}"])
        sav = hetero.savings_summary(chip)
        return rows, sav

    (rows, sav), us = _timed(run)
    _write("table6_cross_penalty", ["network", "dE_pct", "dD_pct",
                                    "dEDP_pct"], rows)
    es = max(v["energy_saved"] for v in sav.values())
    ed = max(v["edp_saved"] for v in sav.values())
    _emit("table6_cross_penalty", us,
          f"max saved: energy {es:.0f}% / EDP {ed:.0f}% (paper 36%/67%)")


def bench_table7_8(nets):
    """Tables 7–8: Alg. II distribution on the paper's two core configs."""
    cfg3 = accelerator.AcceleratorConfig(array_rows=32, array_cols=32,
                                         gb_psum_kb=54, gb_ifmap_kb=54)
    cfg4 = accelerator.AcceleratorConfig(array_rows=12, array_cols=14,
                                         gb_psum_kb=216, gb_ifmap_kb=54)

    def run():
        rows = []
        for net in nets:
            layers = topology.get_network(net)
            cat1 = net in topology.CATEGORY_1
            cfg, k = (cfg3, 3) if cat1 else (cfg4, 4)
            rep = energymodel.simulate_network(cfg, layers, net)
            bb = partition.partition_network(rep, k)
            opt = partition.partition_network(rep, k, "dp")
            rows.append([net, k,
                         " ".join(f"({a},{b})" for a, b in bb.table_row()),
                         f"{bb.speedup:.2f}", f"{opt.speedup:.2f}"])
        return rows

    rows, us = _timed(run)
    _write("table7_8_distribution", ["network", "cores", "(l_init,n_C)",
                                     "speedup_bb", "speedup_optimal"], rows)
    s = [float(r[3]) for r in rows]
    _emit("table7_8_distribution", us,
          f"speedups {min(s):.2f}-{max(s):.2f} (paper 2.01-3.92)")


def bench_autoshard():
    """TPU adaptation: sharding-policy DSE + fleet design (Table-5 analogue)."""
    from repro.configs import ARCHS

    def run():
        rows = []
        for name, cfg in ARCHS.items():
            scored = autoshard.sweep(cfg, n_chips=256, seq_len=4096,
                                     global_batch=256)
            best, s = scored[0]
            rows.append([name, best.name, f"{s * 1e3:.2f}"])
        fleet = autoshard.design_fleet(
            {n: c for n, c in ARCHS.items()}, n_chips=256, seq_len=4096,
            global_batch=256, max_policies=3)
        return rows, fleet

    (rows, fleet), us = _timed(run)
    _write("autoshard_policies", ["arch", "best_policy", "step_ms"], rows)
    _emit("autoshard_fleet", us,
          f"{len(fleet['policies'])} fleet policies cover all 10 archs: "
          + ", ".join(fleet["policies"]))


def bench_pipeline_stages():
    """B&B pipeline staging from the TPU cost model (Alg. II, TPU edition)."""
    from repro.configs import ARCHS
    from repro.core.tpu_costmodel import layer_costs

    def run():
        rows = []
        for name in ("qwen2.5-32b", "qwen2-vl-72b", "recurrentgemma-9b",
                     "arctic-480b"):
            cfg = ARCHS[name]
            costs = layer_costs(cfg, ShardingPolicy("p", dp=64, tp=4),
                                seq_len=4096, global_batch=256)
            lat = [c.time_s for c in costs]
            for k in (2, 4):
                p = partition.bb_partition(lat, k)
                rows.append([name, k, f"{p.speedup:.2f}",
                             f"{p.pipeline_latency * 1e3:.2f}"])
        return rows

    rows, us = _timed(run)
    _write("pipeline_stages", ["arch", "stages", "speedup",
                               "stage_ms"], rows)
    s = [float(r[2]) for r in rows if r[1] == 4]
    _emit("pipeline_stages", us,
          f"4-stage speedups {min(s):.2f}-{max(s):.2f}")


def bench_fig5_6_7(sweeps):
    """Fig. 5/6/7: energy & latency curves vs GB sizes per array (CSV)."""
    def run():
        rows = []
        for net in ("VGG16", "ResNet50"):
            sw = sweeps.get(net)
            if sw is None:
                return []
            for a, arr in enumerate(sw.arrays):
                for pi, ps in enumerate(sw.psum_kb):
                    for ii, ifm in enumerate(sw.ifmap_kb):
                        rows.append([net, f"{arr[0]}x{arr[1]}", ps, ifm,
                                     f"{sw.energy[a, pi, ii]:.6e}",
                                     f"{sw.latency[a, pi, ii]:.6e}"])
        return rows

    rows, us = _timed(run)
    if rows:
        _write("fig5_6_7_curves", ["network", "array", "gb_psum_kb",
                                   "gb_ifmap_kb", "energy_pj",
                                   "latency_ns"], rows)
        _emit("fig5_6_7_curves", us, f"{len(rows)} curve points")


def bench_roofline_table():
    """§Roofline: aggregate the dry-run JSON cells into the report table."""
    import json

    def run():
        rows = []
        for f in sorted(Path("experiments/dryrun").glob("*__single.json")):
            r = json.loads(f.read_text())
            if r.get("status") != "ok":
                continue
            rl = r["roofline"]
            rows.append([
                r["arch"], r["shape"], f"{r['per_device_gib']:.2f}",
                f"{rl['compute_s']:.4f}", f"{rl['memory_s']:.4f}",
                f"{rl['collective_s']:.4f}", rl["bottleneck"],
                f"{rl['useful_flops_ratio']:.3f}", f"{rl['mfu']:.4f}"])
        return rows

    rows, us = _timed(run)
    if rows:
        _write("roofline_single_pod", ["arch", "shape", "gib_per_dev",
                                       "compute_s", "memory_s",
                                       "collective_s", "bottleneck",
                                       "useful_flops", "mfu"], rows)
        bn = [r[6] for r in rows]
        _emit("roofline_single_pod", us,
              f"{len(rows)} cells; bottlenecks: "
              f"compute={bn.count('compute')} memory={bn.count('memory')} "
              f"collective={bn.count('collective')}")
    else:
        _emit("roofline_single_pod", us, "no dry-run cells found (run "
              "python -m repro.launch.dryrun first)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    nets = QUICK_NETS if args.quick else PAPER_NETS

    print("name,us_per_call,derived")
    sweeps, us = _timed(lambda: _sweeps(nets))
    _emit("dse_sweep_all", us, f"{len(nets)} networks x 150 configs")
    bench_dse_scale(quick=args.quick)
    bench_table1_2(sweeps)
    bench_table3(sweeps)
    bench_table4(sweeps)
    chip = bench_table5(sweeps)
    bench_table6(sweeps, chip)
    bench_table7_8(nets)
    bench_fig5_6_7(sweeps)
    bench_autoshard()
    bench_pipeline_stages()
    bench_roofline_table()


if __name__ == "__main__":
    main()
