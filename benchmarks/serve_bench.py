"""Service + fault-tolerance benchmark: writes BENCH_serve[.quick].json.

    PYTHONPATH=src python -m benchmarks.serve_bench [--quick]

Three measurements, mirroring the robustness claims the fault suite
proves functionally (tests/test_faults.py, tests/test_stream_resume.py):

* ``service`` — a :class:`repro.serving.dse_service.DSEService` draining a
  seeded mix of best-config / best-chip / Pareto queries: queries/sec and
  latency percentiles, all answers coalesced per compiled sweep;
* ``recovery`` — a stream killed at ~90% of its chunks and resumed from
  the last exported fold state: ``recovery_ratio`` = resume time / full
  uninterrupted time (the crash-safety tax; floor-checked to stay <= 20%),
  plus ``max_rel_err_resume`` which MUST be 0.0 — resume is bit-exact;
* ``chaos`` — the service under the CI seed matrix of random fault plans:
  every accepted query answered, zero errors;
* ``restart`` (schema 2) — the DURABLE service process-killed mid-sweep
  (``FaultPlan.pkill_at``), restarted over the same ``state_dir``, and
  drained: ``recovery_tax`` = (killed + restart time) / uninterrupted
  durable time − 1 — both sides pay the journal/store fsyncs, so the tax
  isolates the kill + replay overhead itself (floor-checked ≤ 25% on
  full runs) — ``max_rel_err_restart`` MUST be 0.0
  (replayed answers bit-identical to the uninterrupted run, tuples and
  JSON-round-tripped lists compared as equal) with zero duplicate rids,
  and a third warm launch over the same state answers the whole mix from
  the persistent store — ``warm_hit_ratio`` floor-checked ≥ 0.8;
* ``verify`` (schema 3) — the silent-corruption defense of
  :mod:`repro.ft.verify`: a seeded finite-corruption matrix
  (chaos seeds × both tensors × first/middle/last chunk, each scaling
  ONE streamed element by 1e-3) run under full shadow sampling —
  ``detection_rate`` MUST be 1.0 and every resume-retry past the
  poisoned chunk must reproduce the clean answers bit-identically
  (``recompute_parity``/``max_rel_err_verify``); plus the verification
  tax at the DEFAULT 1/16 sampling, ``overhead`` = verified stream time
  / unverified − 1, floor-checked ≤ 10% on full runs.

``benchmarks/check_floors.py`` asserts the guardrails in
``benchmarks/floors.json`` (``serve`` section; ``*_max`` keys are
ceilings).  Schema documented in docs/bench_schema.md.
"""

from __future__ import annotations

import argparse
import json
import platform
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import energymodel, topology
from repro.core.accelerator import ConfigGrid, extended_grid
from repro.ft.faults import FaultPlan, ProcessKill, inject_chunk_faults
from repro.ft.verify import ShadowMismatchError, StreamVerifier
from repro.serving.dse_service import DSEService

BENCH_SERVE_JSON = Path("BENCH_serve.json")
BENCH_SERVE_QUICK_JSON = Path("BENCH_serve.quick.json")

QUICK_NETS = ("AlexNet", "MobileNet", "ResNet50")
FULL_NETS = ("AlexNet", "VGG16", "GoogleNet", "MobileNet", "ResNet50",
             "MobileNetV2")
CHAOS_SEEDS = (0, 1, 2)


def _service_metrics(grid, networks, *, n_queries: int,
                     chunk_size: int) -> dict:
    svc = DSEService(grid, networks, chunk_size=chunk_size,
                     max_queue=n_queries)
    names = list(networks)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(n_queries):
        kind = ("best_config", "best_chip", "pareto")[int(rng.integers(3))]
        svc.submit(kind,
                   network=(names[int(rng.integers(len(names)))]
                            if kind != "best_config" else None),
                   deadline=float(rng.choice([1.5, 2.0, 3.0])))
    responses, drained = svc.run_until_drained(max_steps=200)
    elapsed = time.perf_counter() - t0
    h = svc.health()
    return dict(n_cfg=grid.n, n_queries=n_queries, served=len(responses),
                drained=bool(drained), elapsed_s=elapsed,
                queries_per_sec=len(responses) / elapsed,
                p50_s=h["p50_s"], p99_s=h["p99_s"],
                degraded=h["degraded"], rejected=h["rejected"],
                errors=h["errors"],
                coalesced_batches=h["coalesced_batches"],
                sweep_cache_misses=h["sweep_cache_misses"])


def _recovery_metrics(grid, networks, *, chunk_size: int) -> dict:
    """Kill at ~90% of chunks, resume from the last checkpoint; the ratio
    of resume time to uninterrupted time is the crash-safety tax."""
    kw = dict(topk=8, bound=0.05, chunk_size=chunk_size)
    n_chunks = -(-grid.n // chunk_size)
    kill_at = max(1, int(n_chunks * 0.9))

    energymodel.stream_layer_topk(grid, networks, **kw)   # warm jit caches
    t0 = time.perf_counter()
    ref = energymodel.stream_layer_topk(grid, networks, **kw)
    t_full = time.perf_counter() - t0

    states = []
    try:
        with inject_chunk_faults(FaultPlan(kill_at=kill_at)):
            energymodel.stream_layer_topk(grid, networks,
                                          on_chunk=states.append, **kw)
    except Exception:
        pass
    export = states[-1].export_state()

    t0 = time.perf_counter()
    res = energymodel.stream_layer_topk(grid, networks,
                                        resume_from=export, **kw)
    t_resume = time.perf_counter() - t0

    err = 0.0
    for got, want in ((res.min_metric, ref.min_metric),
                      (res.topk_metric, ref.topk_metric)):
        d = np.abs(np.asarray(got) - np.asarray(want))
        err = max(err, float(np.max(d / np.maximum(np.abs(want), 1e-30))))
    assert (np.asarray(res.argmin) == np.asarray(ref.argmin)).all()
    return dict(n_chunks=n_chunks, kill_chunk=kill_at,
                t_full_s=t_full, t_resume_s=t_resume,
                recovery_ratio=t_resume / t_full,
                max_rel_err_resume=err)


def _chaos_metrics(grid, networks, *, chunk_size: int) -> dict:
    n_chunks = -(-grid.n // chunk_size)
    served = errors = degraded = 0
    for seed in CHAOS_SEEDS:
        svc = DSEService(grid, networks, chunk_size=chunk_size,
                         max_retries=30, backoff_s=1e-4)
        plan = FaultPlan.random(seed, n_chunks, p_fail=0.3, p_corrupt=0.2)
        with inject_chunk_faults(plan):
            for kind in ("best_config", "best_chip"):
                svc.submit(kind, deadline=2.0)
            out, drained = svc.run_until_drained(max_steps=100)
        assert drained
        served += len(out)
        errors += sum(not r.ok for r in out)
        degraded += sum(r.degraded for r in out)
    return dict(seeds=list(CHAOS_SEEDS), served=served, errors=errors,
                degraded=degraded)


def _max_rel_err(got, want):
    """Structural max-rel-err: tuples and lists compare as equal (JSON
    round trips turn tuples into lists), shapes/keys must match exactly,
    numeric leaves contribute their relative difference, any other
    mismatch is +inf."""
    if isinstance(got, dict) and isinstance(want, dict):
        if sorted(got) != sorted(want):
            return float("inf")
        return max((_max_rel_err(got[k], want[k]) for k in got),
                   default=0.0)
    if isinstance(got, (list, tuple)) and isinstance(want, (list, tuple)):
        if len(got) != len(want):
            return float("inf")
        return max((_max_rel_err(g, w) for g, w in zip(got, want)),
                   default=0.0)
    if (isinstance(got, (int, float)) and isinstance(want, (int, float))
            and not isinstance(got, bool) and not isinstance(want, bool)):
        g, w = float(got), float(want)
        if g == w:                      # covers inf == inf
            return 0.0
        if not (np.isfinite(g) and np.isfinite(w)):
            return float("inf")
        return abs(g - w) / max(abs(w), 1e-30)
    return 0.0 if got == want else float("inf")


def _restart_metrics(grid, networks, *, n_queries: int,
                     chunk_size: int) -> dict:
    """Kill the durable service mid-sweep, restart over its state_dir,
    drain, and compare against the uninterrupted run; then measure the
    warm-restart path that answers the same mix from the store."""
    names = list(networks)

    def submit_mix(svc):
        rng = np.random.default_rng(7)
        for _ in range(n_queries):
            kind = ("best_config", "best_chip",
                    "pareto")[int(rng.integers(3))]
            svc.submit(kind,
                       network=(names[int(rng.integers(len(names)))]
                                if kind != "best_config" else None),
                       deadline=float(rng.choice([1.5, 2.0, 3.0])))

    def mk(state_dir):
        return DSEService(grid, networks, chunk_size=chunk_size,
                          max_queue=n_queries, state_dir=state_dir)

    warm = mk(None)                      # warm the jit caches first so the
    submit_mix(warm)                     # timed runs compare folds, not
    warm.run_until_drained()             # traces

    # the clean reference is ALSO durable (fresh state dir): recovery_tax
    # isolates what the kill + journal-replay restart costs, not what
    # durability itself costs (both sides pay the journal/store fsyncs)
    with tempfile.TemporaryDirectory() as sd_clean:
        t0 = time.perf_counter()
        clean = mk(sd_clean)
        submit_mix(clean)
        clean_out, drained = clean.run_until_drained()
        t_clean = time.perf_counter() - t0
        clean.close()
    assert drained
    by_rid = {r.rid: r for r in clean_out}

    n_chunks = -(-grid.n // chunk_size)
    kill_chunk = max(1, n_chunks // 2)
    with tempfile.TemporaryDirectory() as sd:
        t0 = time.perf_counter()
        s1 = mk(sd)
        submit_mix(s1)
        try:
            with inject_chunk_faults(FaultPlan(pkill_at=kill_chunk)):
                s1.run_until_drained()
        except ProcessKill:
            pass
        t_killed = time.perf_counter() - t0
        killed_out = list(s1.responses)  # delivered before the kill
        s1.close()

        t0 = time.perf_counter()
        s2 = mk(sd)                      # journal replay + ckpt resume
        replayed_out, drained = s2.run_until_drained()
        t_restart = time.perf_counter() - t0
        assert drained
        s2.close()

        all_out = killed_out + replayed_out
        rids = [r.rid for r in all_out]
        duplicates = len(rids) - len(set(rids))
        err = 0.0 if len(all_out) == len(clean_out) else float("inf")
        for r in all_out:
            err = max(err, _max_rel_err(r.answer, by_rid[r.rid].answer))

        t0 = time.perf_counter()
        s3 = mk(sd)                      # warm restart: store-served
        submit_mix(s3)
        warm_out, drained = s3.run_until_drained()
        t_warm = time.perf_counter() - t0
        assert drained
        hits = s3.stats["answer_hits"]
        s3.close()

    return dict(
        n_queries=n_queries, n_chunks=n_chunks, kill_chunk=kill_chunk,
        t_clean_s=t_clean, t_killed_s=t_killed, t_restart_s=t_restart,
        recovery_tax=(t_killed + t_restart) / t_clean - 1.0,
        max_rel_err_restart=err,
        duplicate_responses=duplicates,
        served_before_kill=len(killed_out),
        served_after_restart=len(replayed_out),
        t_warm_s=t_warm,
        warm_hit_ratio=hits / max(len(warm_out), 1),
        warm_restart_speedup=t_clean / max(t_warm, 1e-9))


def _verify_metrics(grid, networks, *, chunk_size: int) -> dict:
    """Silent-corruption defense: seeded finite-perturbation matrix at
    full shadow sampling (detection_rate MUST be 1.0, resume-retries
    bit-identical to the clean run) + the verification tax at the
    default 1/16 sampling (both sides on the numpy fold path, so the
    ratio isolates the checks, not backend dispatch)."""
    kw = dict(topk=8, bound=0.05, chunk_size=chunk_size, backend="numpy")
    n_chunks = -(-grid.n // chunk_size)
    ref = energymodel.stream_layer_topk(grid, networks, **kw)

    # -- detection matrix: every injection must raise with provenance,
    #    and the service-style resume-retry must recover exactly
    chunks = sorted({0, n_chunks // 2, n_chunks - 1})
    injected = detected = parity = 0
    err_max = 0.0
    for seed in CHAOS_SEEDS:
        for target in ("e", "t"):
            for ci in chunks:
                injected += 1
                plan = FaultPlan(perturb_at={ci: 1e-3}, seed=seed,
                                 target=target)
                states = []
                try:
                    with inject_chunk_faults(plan):
                        energymodel.stream_layer_topk(
                            grid, networks, on_chunk=states.append,
                            verify=StreamVerifier(verify_fraction=1.0),
                            **kw)
                except ShadowMismatchError as err:
                    assert err.chunk == ci and err.mismatches
                    detected += 1
                # poisoned chunk never committed (perturb pops once):
                # retry from the last good fold state, re-verified
                res = energymodel.stream_layer_topk(
                    grid, networks,
                    resume_from=states[-1] if states else None,
                    verify=StreamVerifier(verify_fraction=1.0), **kw)
                exact = all(
                    np.array_equal(np.asarray(g), np.asarray(w))
                    for g, w in ((res.topk_metric, ref.topk_metric),
                                 (res.topk_idx, ref.topk_idx),
                                 (res.min_metric, ref.min_metric),
                                 (res.argmin, ref.argmin)))
                parity += int(exact)
                if not exact:
                    d = np.abs(np.asarray(res.topk_metric)
                               - np.asarray(ref.topk_metric))
                    err_max = max(err_max, float(np.max(
                        d / np.maximum(np.abs(ref.topk_metric), 1e-30))))

    # -- overhead of the DEFAULT sampling vs an unverified stream
    def best_of(f, n=3):
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            f()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_plain = best_of(lambda: energymodel.stream_layer_topk(
        grid, networks, **kw))
    default_fraction = 1.0 / 16.0
    ver = StreamVerifier(verify_fraction=default_fraction)
    t_verify = best_of(lambda: energymodel.stream_layer_topk(
        grid, networks, verify=ver, **kw))

    return dict(
        n_chunks=n_chunks, injected=injected, detected=detected,
        detection_rate=detected / injected,
        recompute_parity=parity / injected,
        max_rel_err_verify=err_max,
        shadow_checks=ver.stats["shadow_checks"],
        invariant_checks=ver.stats["invariant_checks"],
        verify_fraction=default_fraction,
        t_plain_s=t_plain, t_verify_s=t_verify,
        overhead=t_verify / t_plain - 1.0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small grid + fewer queries (CI guardrail mode)")
    args = ap.parse_args()

    if args.quick:
        grid = ConfigGrid.product()                       # 150 points
        nets = {n: topology.get_network(n) for n in QUICK_NETS}
        n_queries, chunk = 8, 16
        out_path = BENCH_SERVE_QUICK_JSON
    else:
        grid = extended_grid()                            # 5,400 points
        nets = {n: topology.get_network(n) for n in FULL_NETS}
        n_queries, chunk = 24, 256
        out_path = BENCH_SERVE_JSON

    payload = dict(
        schema=3,
        quick=bool(args.quick),
        host=platform.node(),
        python=platform.python_version(),
        service=_service_metrics(grid, nets, n_queries=n_queries,
                                 chunk_size=chunk),
        recovery=_recovery_metrics(grid, nets, chunk_size=chunk),
        chaos=_chaos_metrics(grid, nets, chunk_size=chunk),
        restart=_restart_metrics(grid, nets, n_queries=n_queries,
                                 chunk_size=chunk),
        verify=_verify_metrics(grid, nets, chunk_size=chunk),
    )
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    svc = payload["service"]
    rec = payload["recovery"]
    rst = payload["restart"]
    ver = payload["verify"]
    print(f"{out_path}: {svc['served']}/{svc['n_queries']} queries at "
          f"{svc['queries_per_sec']:.2f} q/s, recovery_ratio="
          f"{rec['recovery_ratio']:.3f}, chaos errors="
          f"{payload['chaos']['errors']}, recovery_tax="
          f"{rst['recovery_tax']:.3f}, warm_hit_ratio="
          f"{rst['warm_hit_ratio']:.2f}, verify detection="
          f"{ver['detected']}/{ver['injected']}, verify overhead="
          f"{ver['overhead']:.3f}")


if __name__ == "__main__":
    main()
