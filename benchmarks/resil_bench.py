"""Resilience benchmark: writes BENCH_resil[.quick].json.

    PYTHONPATH=src python -m benchmarks.resil_bench [--quick]

Three measurements, mirroring the fault-aware resilience layer the test
suite proves functionally (tests/test_resilience.py):

* ``batch`` — the scenario-batched re-schedule solver: every
  (chip × network × fault-scenario) problem of a sampled chip set is
  solved by ONE ``batch_schedule_hetero(strict=False)`` call and by the
  per-scenario ``schedule_hetero_oracle`` python loop.  ``speedup`` is
  the loop/batch time ratio (floor-checked ≥ 10× on full runs) and
  ``max_rel_err_resil`` MUST stay at 0.0 — the batch is bit-exact,
  including +inf bottlenecks on scenarios that kill every core;
* ``codesign`` — :func:`repro.core.hetero.resilience_codesign` over the
  candidate-chip enumeration: ``front_contains_nominal`` (the
  (nominal, worst-case) dominance front must contain the nominal-only
  winner — floor-checked ≥ 1), front size, and the worst-case overhead
  the robust pick saves vs the nominal pick; plus the deadline mode
  (``deadline=2.0``) re-solving the same enumeration with the
  energy-aware slack pass — ``slack_dominance_ok`` (floor-checked ≥ 1)
  requires slack energy to weakly dominate the latency-argmin energy on
  every cell both runs can schedule;
* ``chaos`` — a :class:`repro.serving.dse_service.DSEService` under the
  CI seed matrix of chunk-fault plans, each seed ending in a
  :meth:`fault_event` re-schedule: every query answered, zero errors.

``benchmarks/check_floors.py`` asserts the guardrails in
``benchmarks/floors.json`` (``resil`` section; ``*_max`` keys are
ceilings).  Schema documented in docs/bench_schema.md.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import numpy as np

from repro.core import energymodel, hetero, partition, topology
from repro.core.accelerator import ConfigGrid, extended_grid
from repro.ft import hw_faults
from repro.ft.faults import FaultPlan, inject_chunk_faults
from repro.serving.dse_service import DSEService

BENCH_RESIL_JSON = Path("BENCH_resil.json")
BENCH_RESIL_QUICK_JSON = Path("BENCH_resil.quick.json")

QUICK_NETS = ("AlexNet", "MobileNet", "ResNet50")
FULL_NETS = ("AlexNet", "VGG16", "GoogleNet", "MobileNet", "ResNet50",
             "MobileNetV2")
CHAOS_SEEDS = (0, 1, 2)


def _build_problems(grid, networks, chips, *, seed: int):
    """Sampled chips × {nominal, core losses, degradations} × networks
    as ONE stacked (lat, counts, n_layers) problem block plus the
    per-problem metadata the oracle loop needs."""
    lens = energymodel.network_layer_counts(networks)
    n_net = len(networks)
    per_chip = []
    for ci, (ty, cn) in enumerate(chips):
        scens = hw_faults.all_single_core_failures(cn)
        scens += hw_faults.random_degradations(seed + ci, grid, ty,
                                               n_scenarios=2)
        # one scenario that kills the whole chip — the infeasible path
        # must round-trip through the batch as +inf, not an exception
        scens.append(hw_faults.FaultScenario(
            "chip_dead", tuple(hw_faults.CoreFailure(t, n=int(c))
                               for t, c in enumerate(cn) if c)))
        batch = hw_faults.expand_scenarios(grid, ty, cn, scens)
        e_l, t_l = energymodel.evaluate_networks(batch.grid, networks,
                                                 per_layer=True)
        per_chip.append(hw_faults.scenario_problems(batch, e_l, t_l, lens))
    t_max = max(p[0].shape[1] for p in per_chip)
    lats, cnts, nls = [], [], []
    for lat, cnt, nl, _en in per_chip:
        pad = t_max - lat.shape[1]
        if pad:
            lat = np.pad(lat, ((0, 0), (0, pad), (0, 0)))
            cnt = np.pad(cnt, ((0, 0), (0, pad)))
        lats.append(lat)
        cnts.append(cnt)
        nls.append(nl)
    return (np.concatenate(lats), np.concatenate(cnts),
            np.concatenate(nls), n_net)


def _batch_metrics(grid, networks, *, n_chips: int, max_types: int,
                   pool_size: int, repeats: int = 3) -> dict:
    probs = hetero.codesign_problems(grid, networks, 4,
                                     max_types=max_types,
                                     pool_size=pool_size)
    rng = np.random.default_rng(0)
    pick = rng.choice(len(probs.chips),
                      size=min(n_chips, len(probs.chips)), replace=False)
    chips = [probs.chips[i] for i in sorted(pick)]
    lat, counts, n_layers, _ = _build_problems(grid, networks, chips,
                                               seed=0)
    n_problems = lat.shape[0]

    partition.batch_schedule_hetero(lat, counts, n_layers=n_layers,
                                    strict=False)          # warm jit
    t_batch = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = partition.batch_schedule_hetero(lat, counts,
                                              n_layers=n_layers,
                                              strict=False)
        t_batch = min(t_batch, time.perf_counter() - t0)

    t0 = time.perf_counter()
    ref = np.empty(n_problems)
    for i in range(n_problems):
        if not (counts[i] > 0).any():
            ref[i] = np.inf
            continue
        ref[i] = partition.schedule_hetero_oracle(
            lat[i, :, :n_layers[i]], counts[i])["bottleneck"]
    t_oracle = time.perf_counter() - t0

    feas = np.isfinite(ref)
    assert (res.feasible == feas).all()
    err = float(np.max(np.abs(res.bottleneck[feas] - ref[feas])
                       / np.maximum(np.abs(ref[feas]), 1e-30),
                       initial=0.0))
    n_exact = int((res.bottleneck[feas] == ref[feas]).sum())
    return dict(n_chips=len(chips), n_problems=n_problems,
                n_infeasible=int((~feas).sum()),
                t_batch_s=t_batch, t_oracle_s=t_oracle,
                speedup=t_oracle / t_batch,
                max_rel_err_resil=err,
                n_exact=n_exact, n_feasible=int(feas.sum()))


def _codesign_metrics(grid, networks, *, max_types: int,
                      pool_size: int) -> dict:
    t0 = time.perf_counter()
    res = hetero.resilience_codesign(grid, networks,
                                     max_types=max_types,
                                     pool_size=pool_size,
                                     degradations=((2, 2), (4, 4)))
    elapsed = time.perf_counter() - t0
    bn, br = res.best_nominal, res.best_robust

    # deadline mode: the same enumeration re-solved with the energy-aware
    # slack pass at 2x each network's single-config minimum — across the
    # cells both runs can schedule, slack energy must weakly dominate
    t0 = time.perf_counter()
    sla = hetero.resilience_codesign(grid, networks,
                                     max_types=max_types,
                                     pool_size=pool_size,
                                     degradations=((2, 2), (4, 4)),
                                     deadline=2.0)
    slack_s = time.perf_counter() - t0
    both = res.feasible & sla.feasible
    with np.errstate(invalid="ignore"):
        saved = 1.0 - sla.energy[both] / res.energy[both]
    return dict(n_chips=res.n_chips,
                n_scenarios=len(res.scenario_names),
                elapsed_s=elapsed,
                front_size=int(res.front.sum()),
                front_contains_nominal=int(bool(res.front[bn])),
                front_contains_robust=int(bool(res.front[br])),
                best_nominal_score=float(res.nominal_score[bn]),
                best_nominal_worst=float(res.worst_score[bn]),
                best_robust_score=float(res.nominal_score[br]),
                best_robust_worst=float(res.worst_score[br]),
                robust_worst_gain=float(res.worst_score[bn]
                                        / res.worst_score[br]),
                slack_deadline=2.0,
                slack_elapsed_s=slack_s,
                slack_moves_total=int(sla.slack_moves.sum()),
                slack_energy_saved_mean_pct=float(100.0 * saved.mean()),
                slack_energy_saved_max_pct=float(100.0 * saved.max()),
                slack_dominance_ok=int(bool(
                    (sla.energy[both]
                     <= res.energy[both] * (1.0 + 1e-9)).all())))


def _chaos_metrics(grid, networks, *, chunk_size: int) -> dict:
    """Chunk faults while serving, then a fault_event re-schedule per
    seed — the service must answer everything, zero errors."""
    n_chunks = -(-grid.n // chunk_size)
    served = errors = degraded = reschedules = 0
    for seed in CHAOS_SEEDS:
        svc = DSEService(grid, networks, chunk_size=chunk_size,
                         max_retries=30, backoff_s=1e-4)
        plan = FaultPlan.random(seed, n_chunks, p_fail=0.3, p_corrupt=0.2)
        with inject_chunk_faults(plan):
            svc.submit("best_chip", deadline=2.0)
            out, drained = svc.run_until_drained(max_steps=100)
            assert drained
            chip = out[0].answer
            if out[0].ok and chip.get("feasible"):
                scen = hw_faults.all_single_core_failures(
                    chip["chip_counts"])[seed % len(chip["chip_counts"])]
                svc.fault_event(chip["chip_types"], chip["chip_counts"],
                                scen)
                more, drained = svc.run_until_drained(max_steps=100)
                assert drained
                out += more
                reschedules += svc.stats["reschedules"]
        served += len(out)
        errors += sum(not r.ok for r in out)
        degraded += sum(r.degraded for r in out)
    return dict(seeds=list(CHAOS_SEEDS), served=served, errors=errors,
                degraded=degraded, reschedules=reschedules)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small grid + fewer chips (CI guardrail mode)")
    args = ap.parse_args()

    if args.quick:
        grid = ConfigGrid.product()                       # 150 points
        nets = {n: topology.get_network(n) for n in QUICK_NETS}
        n_chips, max_types, pool, chunk = 8, 2, 4, 16
        out_path = BENCH_RESIL_QUICK_JSON
    else:
        grid = extended_grid()                            # 5,400 points
        nets = {n: topology.get_network(n) for n in FULL_NETS}
        n_chips, max_types, pool, chunk = 24, 3, 6, 256
        out_path = BENCH_RESIL_JSON

    payload = dict(
        schema=1,
        quick=bool(args.quick),
        host=platform.node(),
        python=platform.python_version(),
        batch=_batch_metrics(grid, nets, n_chips=n_chips,
                             max_types=max_types, pool_size=pool),
        codesign=_codesign_metrics(grid, nets, max_types=max_types,
                                   pool_size=pool),
        chaos=_chaos_metrics(grid, nets, chunk_size=chunk),
    )
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    b, c = payload["batch"], payload["codesign"]
    print(f"{out_path}: {b['n_problems']} problems, batch speedup "
          f"{b['speedup']:.1f}x (err {b['max_rel_err_resil']:.1e}), "
          f"front {c['front_size']} chips "
          f"(nominal in front: {c['front_contains_nominal']}), "
          f"chaos errors={payload['chaos']['errors']}")


if __name__ == "__main__":
    main()
